//! `exec` — one workload contract over analytic, event-driven,
//! thread-parallel and process-parallel execution backends.
//!
//! The paper's claim is about *time*: Base-(k+1) reaches exact consensus
//! in finite time at small maximum degree, so decentralized SGD spends
//! less wall-clock per unit of progress. This module makes that claim
//! measurable on every clock through a single contract:
//!
//! ```text
//!            Workload (workload.rs)                Executor
//!   per-node state · local_step · make_payload      backend
//!   combine (missing-peer renormalization) ──┬──► AnalyticExecutor
//!       ConsensusWorkload (f64 gossip)       ├──► SimnetExecutor
//!       TrainingWorkload (DSGD family)       ├──► ThreadedExecutor
//!                        │                   └──► ProcessExecutor
//!                        ▼
//!        ExecTrace: per-round error/loss records +
//!        α–β / event-clock seconds + measured wall-clock +
//!        CommLedger totals (incl. measured bytes_on_wire) +
//!        final node states
//! ```
//!
//! * [`AnalyticExecutor`] — the ideal lock-step loop, with α–β model
//!   seconds on the simulated clock.
//! * [`SimnetExecutor`] — the discrete-event network simulator
//!   (stragglers, lossy/heterogeneous links, BSP or asynchronous gossip);
//!   the simulated clock is the event clock.
//! * [`ThreadedExecutor`] — real OS threads: one node per
//!   [`ThreadPool`](crate::util::threadpool::ThreadPool) worker,
//!   double-buffered payload mailboxes and a real barrier per phase.
//! * [`ProcessExecutor`] — one OS *process* per node shard
//!   ([`shard::ShardPlan`]), gossip as length-prefixed checksummed frames
//!   over Unix-domain sockets ([`wire`]). The backend where a topology's
//!   degree is measured in real serialized bytes
//!   ([`CommLedger::bytes_on_wire`](crate::comm::CommLedger)) and real
//!   IPC wall-clock.
//!
//! The full architecture tour — including a "how to add a backend"
//! walkthrough that builds `ProcessExecutor` step by step — lives in
//! `docs/ARCHITECTURE.md` at the repository root.
//!
//! # Determinism
//!
//! Under the ideal network every backend walks the same trajectory
//! bit-for-bit: combines read payload snapshots (never live neighbor
//! state), accumulate in neighbor-list order, and per-node work is
//! data-independent, so neither thread scheduling, event interleaving nor
//! process placement can reorder any floating-point operation. Payloads
//! that cross a process boundary are serialized as exact bit patterns.
//! The cross-executor equivalence suite (`tests/exec_equivalence.rs`)
//! pins this at n ∈ {8, 64} for both shipped workloads, all four
//! backends.
//!
//! # Buffer ownership
//!
//! Steady-state rounds allocate nothing in the engines themselves —
//! zero heap allocations end to end for both consensus and training on
//! the serial analytic backend (pinned by `tests/alloc_regression.rs`;
//! the optimizer contract's borrowing variants
//! `pre_mix_into`/`post_mix_into` closed the last d-sized training
//! allocations) — the parallel paths pay only per-dispatch job
//! boxes. Executors own the payload mailboxes and per-node combine
//! scratch, workloads write into them via
//! the scratch-buffer methods ([`Workload::alloc_payload`],
//! [`Workload::make_payload_into`], [`Workload::combine_into`] — whose
//! defaults delegate to the allocating methods, so external workloads
//! keep working unchanged), and the per-round neighbor-availability rows
//! come slot-indexed from one flat reused table. The full ownership map
//! lives in `docs/ARCHITECTURE.md`; `tests/alloc_regression.rs` pins the
//! zero-allocation claim and `basegraph bench` measures the effect.
//!
//! # Adding a backend
//!
//! Implement [`Executor`]: obtain nodes with `Workload::init_nodes`, then
//! per round run `local_step` on every node, snapshot `make_payload`,
//! deliver payloads however the backend likes (drop/delay freely), call
//! `combine` with the per-neighbor availability slice (slot-indexed in
//! neighbor-row order — or `combine_into` once you keep scratch buffers),
//! and `observe` the round record. Fill the record's
//! `cum_*`/`sim_seconds`/`wall_seconds` fields from your ledger and
//! clocks and return an [`ExecTrace`]. The equivalence suite is the
//! acceptance bar: ideal conditions must reproduce [`AnalyticExecutor`]
//! exactly.
//!
//! # Migration
//!
//! The pre-executor free functions (`consensus::simulate`, `train::train`,
//! `simnet::sim_consensus/sim_train` and the `SimTrace`/`SimRunResult`
//! shapes) were deprecated in the executor-API release and have now been
//! **removed**. Construct a [`Workload`] and pick a backend (or let the
//! CLI's `--executor analytic|simnet|threaded|process` flag decide via
//! [`ExecutorKind`]).

pub mod analytic;
pub mod elastic;
pub mod process;
mod scratch;
pub mod shard;
pub mod simnet;
pub mod threaded;
pub mod wire;
pub mod workload;

pub use analytic::AnalyticExecutor;
pub use elastic::run_elastic;
pub use process::{EvictSpec, ProcessExecutor};
pub use shard::ShardPlan;
pub use simnet::SimnetExecutor;
pub use threaded::ThreadedExecutor;
pub use workload::{
    quadratic_fixed_targets, AllocatingWorkload, ConsensusWorkload,
    TrainNode, TrainSpec, TrainingWorkload, Workload,
};

use crate::ckpt::CkptConfig;
use crate::comm::{CommLedger, CostModel};
use crate::metrics::{RoundRecord, RunResult, TimeToTarget};
use crate::simnet::event::Trace;
use crate::simnet::SimConfig;
use crate::telemetry::Telemetry;
use crate::topology::GraphSequence;

/// The unified result of one executed run, whatever the backend.
///
/// Accessor semantics are pinned (this type fixes the historical
/// `SimTrace`/`SimRunResult` drift): on an empty record list
/// `iters_to_reach` and `time_to_reach` both return `None` (never a
/// panic, never `Some(0)`), `final_error` returns NaN and `sim_seconds`
/// returns 0. Whenever `iters_to_reach(tol)` is `Some`, `time_to_reach`
/// and `wall_to_reach` are `Some` for the same record.
#[derive(Debug, Clone)]
pub struct ExecTrace {
    /// Which backend produced this run.
    pub backend: &'static str,
    pub topology: String,
    pub n: usize,
    pub max_degree: usize,
    /// Per-round records. Consensus workloads include a round-0 record
    /// for the initial state; training records start at round 1.
    pub run: RunResult,
    /// Communication totals; `sim_seconds` carries the backend's
    /// simulated clock (α–β model or event clock).
    pub ledger: CommLedger,
    /// Messages lost in flight (event-driven backend only).
    pub drops: u64,
    /// Event trace, when the backend records one.
    pub trace: Trace,
    /// Measured wall-clock seconds for the whole run.
    pub wall_seconds: f64,
    /// Process backend only: measured wire bytes routed through the
    /// coordinator per (src, dst) shard pair — `wire_matrix[src][dst]`
    /// counts both hops of every bundle (src → coordinator → dst).
    /// Empty for the in-process backends, which have no wire.
    pub wire_matrix: Vec<Vec<u64>>,
    /// Final per-node states, widened losslessly to f64.
    pub finals: Vec<Vec<f64>>,
}

impl ExecTrace {
    /// Consensus error per record (NaN where not evaluated).
    pub fn errors(&self) -> Vec<f64> {
        self.run.records.iter().map(|r| r.consensus_error).collect()
    }

    /// Simulated seconds per record.
    pub fn times(&self) -> Vec<f64> {
        self.run.records.iter().map(|r| r.sim_seconds).collect()
    }

    fn reach_record(&self, tol: f64) -> Option<&RoundRecord> {
        self.run
            .records
            .iter()
            .find(|r| !r.consensus_error.is_nan() && r.consensus_error <= tol)
    }

    /// First round (0 = initial state) whose consensus error is `<= tol`.
    pub fn iters_to_reach(&self, tol: f64) -> Option<usize> {
        self.reach_record(tol).map(|r| r.round)
    }

    /// Simulated seconds at which the error first dropped below `tol` —
    /// `Some` exactly when [`ExecTrace::iters_to_reach`] is `Some`.
    pub fn time_to_reach(&self, tol: f64) -> Option<f64> {
        self.reach_record(tol).map(|r| r.sim_seconds)
    }

    /// Measured wall-clock seconds at that same record.
    pub fn wall_to_reach(&self, tol: f64) -> Option<f64> {
        self.reach_record(tol).map(|r| r.wall_seconds)
    }

    /// Did the run reach consensus tolerance `tol`?
    pub fn reached(&self, tol: f64) -> bool {
        self.reach_record(tol).is_some()
    }

    /// Last evaluated consensus error (NaN on an empty trace).
    pub fn final_error(&self) -> f64 {
        self.run
            .records
            .iter()
            .rev()
            .find(|r| !r.consensus_error.is_nan())
            .map(|r| r.consensus_error)
            .unwrap_or(f64::NAN)
    }

    /// Simulated seconds at the end of the run (0 on an empty trace).
    pub fn sim_seconds(&self) -> f64 {
        self.run.records.last().map(|r| r.sim_seconds).unwrap_or(0.0)
    }

    /// Total directed messages sent.
    pub fn messages(&self) -> u64 {
        self.ledger.messages
    }

    /// Total payload bytes moved.
    pub fn bytes(&self) -> u64 {
        self.ledger.bytes
    }

    /// First record crossing a test-accuracy target (training workloads).
    pub fn time_to_accuracy(&self, target: f64) -> Option<TimeToTarget> {
        self.run.time_to_accuracy(target)
    }
}

/// An execution backend: runs any [`Workload`] over a topology's phase
/// sequence for a number of rounds.
pub trait Executor {
    /// Stable backend name (`"analytic"`, `"simnet"`, `"threaded"`,
    /// `"process"`).
    fn backend(&self) -> &'static str;

    /// Execute `rounds` rounds of `w` over `seq` (phases cycle). The
    /// workload is `&mut` only for `init_nodes`; the round loop uses it
    /// shared.
    fn run<W: Workload>(
        &self,
        w: &mut W,
        seq: &GraphSequence,
        rounds: usize,
    ) -> Result<ExecTrace, String>;

    /// [`Executor::run`] under a checkpoint/resume configuration: honor
    /// `ckpt.resume` by restoring a [`crate::ckpt::Snapshot`] before the
    /// first executed round, and `ckpt.policy` by writing round-boundary
    /// snapshots as they come due. The resumed run must be bit-identical
    /// to the uninterrupted one in every model column (finals, records,
    /// ledger counts — `tests/exec_equivalence.rs` pins it); only the
    /// measured columns (`wall_seconds`, `bytes_on_wire`) may differ.
    ///
    /// The default runs plainly when checkpointing is inactive and
    /// refuses cleanly otherwise, so backends opt in explicitly.
    fn run_ckpt<W: Workload>(
        &self,
        w: &mut W,
        seq: &GraphSequence,
        rounds: usize,
        ckpt: &CkptConfig,
    ) -> Result<ExecTrace, String> {
        if ckpt.is_active() {
            return Err(format!(
                "the {} backend does not support checkpoint/resume",
                self.backend()
            ));
        }
        self.run(w, seq, rounds)
    }

    /// [`Executor::run_ckpt`] with a live [`Telemetry`] handle: emit
    /// `run_started`, one `round_completed` per round,
    /// `checkpoint_written` on every snapshot and `run_finished` at the
    /// end (plus worker/bundle events on the process backend). Emission
    /// happens after the round's parallel section, and two same-seed
    /// runs must emit identical streams modulo the measured fields
    /// ([`crate::telemetry::MEASURED_FIELDS`]).
    ///
    /// The default runs plainly when telemetry is off and refuses
    /// cleanly otherwise, so backends opt in explicitly.
    fn run_tel<W: Workload>(
        &self,
        w: &mut W,
        seq: &GraphSequence,
        rounds: usize,
        ckpt: &CkptConfig,
        tele: &Telemetry,
    ) -> Result<ExecTrace, String> {
        if tele.is_on() {
            return Err(format!(
                "the {} backend does not support telemetry",
                self.backend()
            ));
        }
        self.run_ckpt(w, seq, rounds, ckpt)
    }
}

/// CLI-facing backend selector:
/// `--executor analytic|simnet|threaded|process`.
#[derive(Debug, Clone)]
pub enum ExecutorKind {
    Analytic { cost: CostModel, threads: usize },
    Simnet(SimConfig),
    Threaded { cost: CostModel, threads: usize },
    Process {
        cost: CostModel,
        /// Worker-process count (`--shards`).
        shards: usize,
        /// Degree-balanced sharding (`--shard-balance degree`).
        balanced: bool,
        /// Worker binary override (tests/examples; the CLI re-execs
        /// itself).
        worker_bin: Option<std::path::PathBuf>,
        /// Heartbeat eviction (`--churn-evict`): on worker death,
        /// evict the dead shard's nodes and resequence the survivors
        /// at this Base-(k+1) degree (see
        /// [`ProcessExecutor::evict`]).
        evict: Option<usize>,
        /// Fault injection (`--churn-kill <shard>@<round>`): that
        /// worker aborts at the given round boundary.
        kill: Option<(usize, usize)>,
    },
}

impl ExecutorKind {
    /// The default analytic backend (auto thread count, default α–β).
    pub fn analytic() -> Self {
        ExecutorKind::Analytic { cost: CostModel::default(), threads: 0 }
    }

    /// The thread-parallel backend; `threads == 0` = available cores.
    pub fn threaded(threads: usize) -> Self {
        ExecutorKind::Threaded { cost: CostModel::default(), threads }
    }

    /// The process-parallel backend with `shards` worker processes.
    pub fn process(shards: usize) -> Self {
        ExecutorKind::Process {
            cost: CostModel::default(),
            shards,
            balanced: false,
            worker_bin: None,
            evict: None,
            kill: None,
        }
    }

    /// Parse the `--shard-balance contiguous|degree` CLI value.
    pub fn parse_shard_balance(s: &str) -> Result<bool, String> {
        match s.trim().to_lowercase().as_str() {
            "contiguous" => Ok(false),
            "degree" | "degree-balanced" => Ok(true),
            other => Err(format!(
                "unknown shard balance {other:?} (contiguous|degree)"
            )),
        }
    }

    pub fn parse(s: &str) -> Result<ExecutorKind, String> {
        match s.trim().to_lowercase().as_str() {
            "analytic" => Ok(ExecutorKind::analytic()),
            "simnet" => Ok(ExecutorKind::Simnet(SimConfig::ideal())),
            "threaded" => Ok(ExecutorKind::threaded(0)),
            "process" => Ok(ExecutorKind::process(2)),
            other => Err(format!(
                "unknown executor {other:?} \
                 (analytic|simnet|threaded|process)"
            )),
        }
    }

    /// The one CLI surface for backend selection: `--executor` (with
    /// `default` when absent) plus every backend knob — `--threads`,
    /// `--shards`, `--shard-balance`. `train`, `simnet` and `repro` all
    /// parse through here, so a new knob lands in every subcommand at
    /// once.
    pub fn from_args(
        args: &crate::util::cli::Args,
        default: &str,
    ) -> Result<ExecutorKind, String> {
        Ok(ExecutorKind::parse(&args.str_or("executor", default))?
            .with_threads(args.usize_or("threads", 0)?)
            .with_shards(args.usize_or("shards", 2)?)
            .with_shard_balance(ExecutorKind::parse_shard_balance(
                &args.str_or("shard-balance", "contiguous"),
            )?))
    }

    pub fn label(&self) -> &'static str {
        match self {
            ExecutorKind::Analytic { .. } => "analytic",
            ExecutorKind::Simnet(_) => "simnet",
            ExecutorKind::Threaded { .. } => "threaded",
            ExecutorKind::Process { .. } => "process",
        }
    }

    /// Set the worker-thread count (no-op for the event-driven and
    /// process backends).
    pub fn with_threads(self, threads: usize) -> Self {
        match self {
            ExecutorKind::Analytic { cost, .. } => {
                ExecutorKind::Analytic { cost, threads }
            }
            ExecutorKind::Threaded { cost, .. } => {
                ExecutorKind::Threaded { cost, threads }
            }
            s @ (ExecutorKind::Simnet(_) | ExecutorKind::Process { .. }) => {
                s
            }
        }
    }

    /// Set the worker-process count (no-op for the other backends).
    pub fn with_shards(self, shards: usize) -> Self {
        match self {
            ExecutorKind::Process {
                cost,
                balanced,
                worker_bin,
                evict,
                kill,
                ..
            } => ExecutorKind::Process {
                cost,
                shards,
                balanced,
                worker_bin,
                evict,
                kill,
            },
            other => other,
        }
    }

    /// Choose degree-balanced sharding (no-op for the other backends).
    pub fn with_shard_balance(self, balanced: bool) -> Self {
        match self {
            ExecutorKind::Process {
                cost,
                shards,
                worker_bin,
                evict,
                kill,
                ..
            } => ExecutorKind::Process {
                cost,
                shards,
                balanced,
                worker_bin,
                evict,
                kill,
            },
            other => other,
        }
    }

    /// Enable heartbeat eviction at Base-(k+1) degree `k` on the
    /// process backend (`--churn-evict`; no-op for the others).
    pub fn with_evict(self, evict: Option<usize>) -> Self {
        match self {
            ExecutorKind::Process {
                cost,
                shards,
                balanced,
                worker_bin,
                kill,
                ..
            } => ExecutorKind::Process {
                cost,
                shards,
                balanced,
                worker_bin,
                evict,
                kill,
            },
            other => other,
        }
    }

    /// Inject a worker abort at `(shard, round)` on the process backend
    /// (`--churn-kill`; no-op for the others).
    pub fn with_kill(self, kill: Option<(usize, usize)>) -> Self {
        match self {
            ExecutorKind::Process {
                cost,
                shards,
                balanced,
                worker_bin,
                evict,
                ..
            } => ExecutorKind::Process {
                cost,
                shards,
                balanced,
                worker_bin,
                evict,
                kill,
            },
            other => other,
        }
    }

    /// Point the process backend at an explicit worker binary — needed
    /// from test harnesses and examples, whose own executable is not the
    /// `basegraph` CLI (no-op for the other backends).
    pub fn with_worker_bin(self, bin: impl Into<std::path::PathBuf>) -> Self {
        match self {
            ExecutorKind::Process {
                cost,
                shards,
                balanced,
                evict,
                kill,
                ..
            } => ExecutorKind::Process {
                cost,
                shards,
                balanced,
                worker_bin: Some(bin.into()),
                evict,
                kill,
            },
            other => other,
        }
    }

    /// Set the α–β cost model; for the event-driven backend this
    /// overrides every link's cost.
    pub fn with_cost(self, cost: CostModel) -> Self {
        match self {
            ExecutorKind::Analytic { threads, .. } => {
                ExecutorKind::Analytic { cost, threads }
            }
            ExecutorKind::Threaded { threads, .. } => {
                ExecutorKind::Threaded { cost, threads }
            }
            ExecutorKind::Process {
                shards,
                balanced,
                worker_bin,
                evict,
                kill,
                ..
            } => ExecutorKind::Process {
                cost,
                shards,
                balanced,
                worker_bin,
                evict,
                kill,
            },
            ExecutorKind::Simnet(mut sim) => {
                sim.links.override_cost(Some(cost.alpha), Some(cost.beta));
                ExecutorKind::Simnet(sim)
            }
        }
    }

    /// Replace the simnet configuration (no-op for the other backends).
    pub fn with_sim(self, sim: SimConfig) -> Self {
        match self {
            ExecutorKind::Simnet(_) => ExecutorKind::Simnet(sim),
            other => other,
        }
    }

    /// Dispatch to the concrete backend.
    pub fn run<W: Workload>(
        &self,
        w: &mut W,
        seq: &GraphSequence,
        rounds: usize,
    ) -> Result<ExecTrace, String> {
        self.run_ckpt(w, seq, rounds, &CkptConfig::default())
    }

    /// Dispatch with a checkpoint/resume configuration (the CLI's
    /// `--checkpoint-every`/`--resume` path; see [`CkptConfig`]).
    pub fn run_ckpt<W: Workload>(
        &self,
        w: &mut W,
        seq: &GraphSequence,
        rounds: usize,
        ckpt: &CkptConfig,
    ) -> Result<ExecTrace, String> {
        self.run_tel(w, seq, rounds, ckpt, &Telemetry::off())
    }

    /// Dispatch with checkpointing *and* a telemetry handle (the CLI's
    /// `--telemetry`/`--telemetry-http` path; see
    /// [`crate::telemetry`]). All four backends emit the shared event
    /// set; [`Telemetry::off`] makes this identical to
    /// [`ExecutorKind::run_ckpt`].
    pub fn run_tel<W: Workload>(
        &self,
        w: &mut W,
        seq: &GraphSequence,
        rounds: usize,
        ckpt: &CkptConfig,
        tele: &Telemetry,
    ) -> Result<ExecTrace, String> {
        match self {
            ExecutorKind::Analytic { cost, threads } => {
                AnalyticExecutor { cost: *cost, threads: *threads }
                    .run_tel(w, seq, rounds, ckpt, tele)
            }
            ExecutorKind::Simnet(sim) => {
                SimnetExecutor::new(sim.clone())
                    .run_tel(w, seq, rounds, ckpt, tele)
            }
            ExecutorKind::Threaded { cost, threads } => {
                ThreadedExecutor::new(*cost, *threads)
                    .run_tel(w, seq, rounds, ckpt, tele)
            }
            ExecutorKind::Process {
                cost,
                shards,
                balanced,
                worker_bin,
                evict,
                kill,
            } => {
                let mut ex = ProcessExecutor::new(*cost, *shards)
                    .with_balanced(*balanced);
                ex.worker_bin = worker_bin.clone();
                ex.evict = evict.map(|k| EvictSpec { k });
                ex.fault_crash = *kill;
                ex.ckpt = ckpt.clone();
                ex.tele = tele.clone();
                ex.run(w, seq, rounds)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn empty_trace() -> ExecTrace {
        ExecTrace {
            backend: "analytic",
            topology: "none".into(),
            n: 0,
            max_degree: 0,
            run: RunResult::default(),
            ledger: CommLedger::default(),
            drops: 0,
            trace: Trace::new(false),
            wall_seconds: 0.0,
            wire_matrix: Vec::new(),
            finals: Vec::new(),
        }
    }

    /// The satellite fix pinned: the old `SimTrace::iters_to_reach`
    /// returned `None` on traces with no crossing while `final_error` /
    /// `sim_seconds` panicked on empty ones, and training results used
    /// different names entirely. `ExecTrace` is total and consistent.
    #[test]
    fn empty_trace_accessors_are_total_and_agree() {
        let t = empty_trace();
        assert_eq!(t.iters_to_reach(1e-9), None);
        assert_eq!(t.time_to_reach(1e-9), None);
        assert_eq!(t.wall_to_reach(1e-9), None);
        assert!(!t.reached(1e-9));
        assert!(t.final_error().is_nan());
        assert_eq!(t.sim_seconds(), 0.0);
        assert!(t.time_to_accuracy(0.5).is_none());
        assert!(t.errors().is_empty());
        assert!(t.times().is_empty());
    }

    #[test]
    fn reach_accessors_pick_the_same_record() {
        let mut t = empty_trace();
        for (round, err, sim_s, wall_s) in [
            (0usize, 1.0, 0.0, 0.001),
            (1, 0.5, 0.2, 0.002),
            (2, 1e-12, 0.4, 0.003),
            (3, 1e-13, 0.6, 0.004),
        ] {
            t.run.records.push(RoundRecord {
                round,
                train_loss: f64::NAN,
                consensus_error: err,
                test_loss: f64::NAN,
                test_acc: f64::NAN,
                sim_seconds: sim_s,
                wall_seconds: wall_s,
                ..Default::default()
            });
        }
        assert_eq!(t.iters_to_reach(1e-9), Some(2));
        assert_eq!(t.time_to_reach(1e-9), Some(0.4));
        assert_eq!(t.wall_to_reach(1e-9), Some(0.003));
        assert!(t.reached(1e-9));
        assert_eq!(t.iters_to_reach(1e-20), None);
        assert_eq!(t.time_to_reach(1e-20), None);
        assert_eq!(t.final_error(), 1e-13);
        assert_eq!(t.sim_seconds(), 0.6);
    }

    #[test]
    fn executor_kind_parses_and_updates() {
        assert_eq!(ExecutorKind::parse("analytic").unwrap().label(), "analytic");
        assert_eq!(ExecutorKind::parse("SIMNET").unwrap().label(), "simnet");
        assert_eq!(ExecutorKind::parse("threaded").unwrap().label(), "threaded");
        assert_eq!(ExecutorKind::parse("process").unwrap().label(), "process");
        assert!(ExecutorKind::parse("gpu").is_err());
        match ExecutorKind::parse("threaded").unwrap().with_threads(7) {
            ExecutorKind::Threaded { threads, .. } => assert_eq!(threads, 7),
            _ => panic!("wrong kind"),
        }
        // with_threads is a no-op on the event-driven backend.
        assert_eq!(
            ExecutorKind::parse("simnet").unwrap().with_threads(3).label(),
            "simnet"
        );
        // Shard knobs only touch the process backend.
        match ExecutorKind::parse("process")
            .unwrap()
            .with_threads(5)
            .with_shards(4)
            .with_shard_balance(true)
        {
            ExecutorKind::Process { shards, balanced, .. } => {
                assert_eq!(shards, 4);
                assert!(balanced);
            }
            _ => panic!("wrong kind"),
        }
        assert!(matches!(
            ExecutorKind::analytic().with_shards(9),
            ExecutorKind::Analytic { .. }
        ));
    }
}
