//! The event-driven backend: one generic discrete-event engine over any
//! [`Workload`], replacing the two near-duplicate drivers
//! (`sim_consensus` / `sim_train`) that previously lived in
//! `simnet::driver`.
//!
//! Sends are seeded from the sparse [`GossipPlan`] schedules: node `j`
//! sends its payload to every node whose neighbor list contains `j` in
//! the current phase (the reverse adjacency), sends serialized per sender
//! (one NIC per node), each one drop-sampled, each arrival an event. The
//! mixing arithmetic is whatever the workload's `combine` does — the same
//! code every other backend runs — so bulk-synchronous execution under an
//! ideal network reproduces [`AnalyticExecutor`](super::AnalyticExecutor)
//! bit-exactly.
//!
//! Two disciplines, selected by [`SimConfig::mode`]:
//! * **Bulk-synchronous** — a barrier per phase: all compute finishes,
//!   every surviving message is delivered, then every node mixes.
//! * **Asynchronous / local-steps** — no barriers: a node that finishes
//!   compute mixes whatever neighbor payloads have arrived (consume-once
//!   mailboxes, missing peers renormalized) and immediately moves on.

use std::collections::{BTreeMap, HashMap};
use std::rc::Rc;
use std::time::Instant;

use super::scratch::AvailTable;
use super::{ExecTrace, Executor, Workload};
use crate::ckpt::{CkptConfig, Snapshot};
use crate::comm::CommLedger;
use crate::metrics::RunResult;
use crate::simnet::event::{EventKind, EventQueue, Trace};
use crate::simnet::{ExecMode, SimConfig};
use crate::telemetry::{Event, Telemetry};
use crate::topology::{GossipPlan, GraphSequence};

/// Per-phase reverse adjacency: `out[src]` lists every `dst` whose
/// neighbor list contains `src` — i.e. where a directed message
/// `src → dst` flows. Lists are dst-ascending, so send order (and with it
/// the whole event schedule) is deterministic.
pub(crate) fn out_adjacency(plan: &GossipPlan) -> Vec<Vec<usize>> {
    let mut out = vec![Vec::new(); plan.n()];
    for (dst, src, _w) in plan.directed_edges() {
        out[src].push(dst);
    }
    out
}

/// Discrete-event execution on a simulated network (stragglers,
/// heterogeneous/lossy links, BSP or async gossip). Single-threaded by
/// design: the event queue is the scheduler.
#[derive(Debug, Clone)]
pub struct SimnetExecutor {
    pub sim: SimConfig,
}

impl SimnetExecutor {
    pub fn new(sim: SimConfig) -> Self {
        SimnetExecutor { sim }
    }
}

impl Executor for SimnetExecutor {
    fn backend(&self) -> &'static str {
        "simnet"
    }

    fn run<W: Workload>(
        &self,
        w: &mut W,
        seq: &GraphSequence,
        rounds: usize,
    ) -> Result<ExecTrace, String> {
        self.run_ckpt(w, seq, rounds, &CkptConfig::default())
    }

    fn run_ckpt<W: Workload>(
        &self,
        w: &mut W,
        seq: &GraphSequence,
        rounds: usize,
        ckpt: &CkptConfig,
    ) -> Result<ExecTrace, String> {
        self.run_tel(w, seq, rounds, ckpt, &Telemetry::off())
    }

    fn run_tel<W: Workload>(
        &self,
        w: &mut W,
        seq: &GraphSequence,
        rounds: usize,
        ckpt: &CkptConfig,
        tele: &Telemetry,
    ) -> Result<ExecTrace, String> {
        // Snapshots capture round boundaries; the async discipline has
        // none (nodes free-run), so checkpointing is BSP-only.
        if ckpt.is_active() && matches!(self.sim.mode, ExecMode::Async) {
            return Err(
                "checkpoint/resume needs round boundaries — the async \
                 simnet mode has none (run bulk-synchronous instead)"
                    .into(),
            );
        }
        let n = seq.n;
        if n == 0 {
            return Err("simnet executor needs n >= 1".into());
        }
        if rounds > 0 && seq.is_empty() {
            return Err(
                "simnet executor needs a non-empty phase sequence".into()
            );
        }
        let t0 = Instant::now();
        let mut nodes = w.init_nodes(n)?;
        let w: &W = w;
        let (n_slots, slot_bytes) = w.comm_shape();
        // Per-link codec policy: transcode in-flight copies crossing
        // remote-class links and charge those links the transcoded byte
        // count. Needs the workload's slot shape — workloads that opt
        // out via `slot_elems` keep run-codec bytes on every link.
        let policy = self.sim.codec_policy;
        let (slot_elems, elem_width) = w.slot_elems();
        let link_codec = move |src: usize, dst: usize| {
            if slot_elems == 0 {
                None
            } else {
                policy.link_codec(src, dst)
            }
        };
        let link_slot_bytes = move |src: usize, dst: usize| match link_codec(
            src, dst,
        ) {
            Some(c) => c.slot_data_bytes(slot_elems, elem_width),
            None => slot_bytes,
        };
        let mut net = self.sim.network(n);
        let mut trace = Trace::new(self.sim.record_trace);
        let mut ledger = CommLedger::default();
        let mut drops = 0u64;
        let mut records = Vec::new();
        let mut start_round = 0usize;
        let mut resume_clock = 0.0f64;
        match ckpt.load_resume(n, &seq.name, rounds)? {
            Some(snap) => {
                for (node, blob) in nodes.iter_mut().zip(&snap.nodes) {
                    w.node_restore(node, blob)?;
                }
                ledger = snap.ledger;
                records = snap.records;
                start_round = snap.round;
                resume_clock = snap.clock;
                // The straggler subset is seed-derived and rebuilt by
                // `self.sim.network(n)` above; the RNG cursor continues
                // the exact compute-jitter/drop stream.
                if let Some((s, spare)) = snap.rng {
                    net.restore_rng(s, spare);
                }
            }
            None => {
                if let Some(mut rec) = w.initial_record(&nodes) {
                    rec.wall_seconds = t0.elapsed().as_secs_f64();
                    records.push(rec);
                }
            }
        }
        tele.emit_with(|| Event::RunStarted {
            label: w.label(),
            backend: "simnet",
            topology: seq.name.clone(),
            n,
            rounds,
            start_round,
        });

        if rounds > 0 {
            let out_adj: Vec<Vec<Vec<usize>>> =
                seq.phases.iter().map(out_adjacency).collect();
            match self.sim.mode {
                ExecMode::BulkSynchronous => {
                    let mut clock = resume_clock;
                    // Round-persistent scratch: arrival flags, the payload
                    // mailbox (written in place after warmup), the
                    // slot-indexed availability table and one shared
                    // combine scratch (the event loop is single-threaded)
                    // — reused every round instead of re-collected.
                    let mut arrived: Vec<Vec<bool>> = vec![Vec::new(); n];
                    let mut mail: Vec<Option<W::Payload>> =
                        (0..n).map(|_| None).collect();
                    // Remote-link transcodes of `mail`, filled only when
                    // the per-link codec policy is active (one recode per
                    // sender per round — every remote link shares it).
                    let mut mail_remote: Vec<Option<W::Payload>> =
                        (0..n).map(|_| None).collect();
                    let mut avail: AvailTable<W::Payload> =
                        AvailTable::new();
                    let mut mix_scratch: Option<W::Payload> = None;
                    for r in start_round..rounds {
                        let pidx = r % seq.len();
                        let plan = &seq.phases[pidx];
                        let mut q = EventQueue::new();
                        for i in 0..n {
                            q.push(
                                clock + net.compute_seconds(i),
                                EventKind::ComputeDone { node: i, round: r },
                            );
                        }
                        // arrived[i][k] <=> the payload of
                        // plan.neighbors(i)[k] made it through this phase.
                        for (i, flags) in arrived.iter_mut().enumerate() {
                            flags.clear();
                            flags.resize(plan.degree(i), false);
                        }
                        let mut barrier_t = clock;
                        let mut failure: Option<String> = None;
                        while let Some(ev) = q.pop() {
                            barrier_t = ev.t;
                            trace.record(ev.t, ev.kind);
                            match ev.kind {
                                EventKind::ComputeDone { node, .. } => {
                                    if let Err(e) = w.local_step(
                                        &mut nodes[node],
                                        node,
                                        r,
                                    ) {
                                        failure =
                                            Some(format!("round {r}: {e}"));
                                        break;
                                    }
                                    let mut t_free = ev.t;
                                    for &dst in &out_adj[pidx][node] {
                                        let sb =
                                            link_slot_bytes(node, dst);
                                        t_free += net.links.send_seconds(
                                            node,
                                            dst,
                                            n_slots as u64 * sb,
                                        );
                                        ledger.record_payload_sends(
                                            n_slots, sb,
                                        );
                                        if net.dropped() {
                                            // One lost bundle loses all
                                            // n_slots logical messages.
                                            drops += n_slots as u64;
                                        } else {
                                            q.push(
                                                t_free,
                                                EventKind::MessageArrive {
                                                    src: node,
                                                    dst,
                                                    msg: 0,
                                                },
                                            );
                                        }
                                    }
                                }
                                EventKind::MessageArrive {
                                    src, dst, ..
                                } => {
                                    let row = plan.neighbors(dst);
                                    if let Ok(k) = row
                                        .binary_search_by_key(&src, |&(p, _)| {
                                            p
                                        })
                                    {
                                        arrived[dst][k] = true;
                                    }
                                }
                                EventKind::PhaseBarrier { .. } => {}
                            }
                        }
                        if let Some(e) = failure {
                            return Err(e);
                        }
                        clock = barrier_t;
                        trace.record(
                            clock,
                            EventKind::PhaseBarrier { round: r },
                        );
                        ledger.advance_clock_to(clock);
                        for _ in 0..n_slots {
                            ledger.bump_round();
                        }
                        // Barrier mix: snapshot every node's payload into
                        // the reused mailbox, combine the survivors
                        // through the slot-indexed table.
                        for (slot, node) in mail.iter_mut().zip(&nodes) {
                            match slot {
                                Some(buf) => w.make_payload_into(node, buf),
                                None => *slot = Some(w.make_payload(node)),
                            }
                        }
                        if let Some(c) =
                            policy.remote.filter(|_| slot_elems > 0)
                        {
                            for (out, src) in
                                mail_remote.iter_mut().zip(&mail)
                            {
                                let src =
                                    src.as_ref().expect("mail filled");
                                match out {
                                    Some(buf) => {
                                        w.payload_recode(src, c, buf)
                                    }
                                    None => {
                                        let mut buf = src.clone();
                                        w.payload_recode(src, c, &mut buf);
                                        *out = Some(buf);
                                    }
                                }
                            }
                        }
                        avail.fill(plan, |i, k, j| {
                            if !arrived[i][k] {
                                None
                            } else if link_codec(j, i).is_some() {
                                mail_remote[j].as_ref()
                            } else {
                                mail[j].as_ref()
                            }
                        });
                        for (i, node) in nodes.iter_mut().enumerate() {
                            let row = avail.row(plan, i);
                            if mix_scratch.is_none() {
                                mix_scratch = Some(w.alloc_payload(node));
                            }
                            let scr =
                                mix_scratch.as_mut().expect("scratch");
                            w.combine_into(node, i, r, plan, row, scr);
                        }
                        let eval = w.is_eval(r, rounds);
                        let mut rec = w.observe(&nodes, r, eval)?;
                        rec.cum_messages = ledger.messages;
                        rec.cum_bytes = ledger.bytes;
                        rec.sim_seconds = ledger.sim_seconds;
                        rec.wall_seconds = t0.elapsed().as_secs_f64();
                        records.push(rec);
                        let committed =
                            records.last().expect("pushed above");
                        tele.emit_with(|| Event::round(committed));
                        // Round-boundary snapshot, when due. The event
                        // queue is empty here (the barrier drained it),
                        // so the virtual clock + net RNG cursor are the
                        // only engine state to carry.
                        if let Some(pol) =
                            ckpt.policy.as_ref().filter(|p| p.due(r))
                        {
                            let (s, spare) = net.rng_state();
                            let snap = Snapshot {
                                topology: seq.name.clone(),
                                n,
                                round: r + 1,
                                nodes: nodes
                                    .iter()
                                    .map(|nd| w.node_ckpt(nd))
                                    .collect::<Result<_, String>>()?,
                                ledger: ledger.clone(),
                                records: records.clone(),
                                clock,
                                rng: Some((s, spare)),
                                roster: ckpt.roster.clone(),
                            };
                            let path = pol.save(&snap)?;
                            tele.emit_with(|| Event::CheckpointWritten {
                                round: r + 1,
                                path: path.display().to_string(),
                            });
                        }
                    }
                }
                ExecMode::Async => {
                    let mut q = EventQueue::new();
                    // In-flight payloads, keyed by message id and
                    // reclaimed on arrival — memory stays O(messages
                    // currently in the air).
                    let mut store: HashMap<usize, Rc<W::Payload>> =
                        HashMap::new();
                    // One combine scratch, recycled across every node's
                    // mix (the event loop is single-threaded).
                    let mut mix_scratch: Option<W::Payload> = None;
                    let mut next_msg = 0usize;
                    let mut mailbox: Vec<BTreeMap<usize, Rc<W::Payload>>> =
                        vec![BTreeMap::new(); n];
                    let mut completed = vec![0usize; rounds];
                    // One NIC per node: sends from consecutive rounds
                    // queue behind each other.
                    let mut nic_free = vec![0.0f64; n];
                    for i in 0..n {
                        q.push(
                            net.compute_seconds(i),
                            EventKind::ComputeDone { node: i, round: 0 },
                        );
                    }
                    while let Some(ev) = q.pop() {
                        trace.record(ev.t, ev.kind);
                        match ev.kind {
                            EventKind::ComputeDone { node, round } => {
                                let pidx = round % seq.len();
                                let plan = &seq.phases[pidx];
                                w.local_step(&mut nodes[node], node, round)
                                    .map_err(|e| {
                                        format!(
                                            "node {node} round {round}: {e}"
                                        )
                                    })?;
                                // Snapshot and send the pre-mix payload.
                                let payload =
                                    Rc::new(w.make_payload(&nodes[node]));
                                // Remote-link transcode, built once per
                                // send fan-out and shared by every
                                // remote destination.
                                let mut remote: Option<Rc<W::Payload>> =
                                    None;
                                let mut t_free = ev.t.max(nic_free[node]);
                                for &dst in &out_adj[pidx][node] {
                                    let lc = link_codec(node, dst);
                                    let sb = match lc {
                                        Some(c) => c.slot_data_bytes(
                                            slot_elems, elem_width,
                                        ),
                                        None => slot_bytes,
                                    };
                                    t_free += net.links.send_seconds(
                                        node,
                                        dst,
                                        n_slots as u64 * sb,
                                    );
                                    ledger.record_payload_sends(
                                        n_slots, sb,
                                    );
                                    if net.dropped() {
                                        drops += n_slots as u64;
                                    } else {
                                        let msg = next_msg;
                                        next_msg += 1;
                                        let p = match lc {
                                            Some(c) => remote
                                                .get_or_insert_with(|| {
                                                    let mut buf = (*payload)
                                                        .clone();
                                                    w.payload_recode(
                                                        &payload, c,
                                                        &mut buf,
                                                    );
                                                    Rc::new(buf)
                                                })
                                                .clone(),
                                            None => payload.clone(),
                                        };
                                        store.insert(msg, p);
                                        q.push(
                                            t_free,
                                            EventKind::MessageArrive {
                                                src: node,
                                                dst,
                                                msg,
                                            },
                                        );
                                    }
                                }
                                nic_free[node] = t_free;
                                // Local-steps gossip: mix with whatever
                                // has arrived (consume-once).
                                let row = plan.neighbors(node);
                                let avail_rc: Vec<Option<Rc<W::Payload>>> =
                                    row.iter()
                                        .map(|&(j, _)| {
                                            mailbox[node].remove(&j)
                                        })
                                        .collect();
                                let avail: Vec<Option<&W::Payload>> =
                                    avail_rc
                                        .iter()
                                        .map(|o| o.as_deref())
                                        .collect();
                                if mix_scratch.is_none() {
                                    mix_scratch =
                                        Some(w.alloc_payload(&nodes[node]));
                                }
                                let scr = mix_scratch
                                    .as_mut()
                                    .expect("scratch");
                                w.combine_into(
                                    &mut nodes[node],
                                    node,
                                    round,
                                    plan,
                                    &avail,
                                    scr,
                                );
                                completed[round] += 1;
                                if completed[round] == n {
                                    ledger.advance_clock_to(ev.t);
                                    for _ in 0..n_slots {
                                        ledger.bump_round();
                                    }
                                    let eval = w.is_eval(round, rounds);
                                    let mut rec =
                                        w.observe(&nodes, round, eval)?;
                                    rec.cum_messages = ledger.messages;
                                    rec.cum_bytes = ledger.bytes;
                                    rec.sim_seconds = ledger.sim_seconds;
                                    rec.wall_seconds =
                                        t0.elapsed().as_secs_f64();
                                    records.push(rec);
                                    let committed = records
                                        .last()
                                        .expect("pushed above");
                                    tele.emit_with(|| {
                                        Event::round(committed)
                                    });
                                }
                                if round + 1 < rounds {
                                    q.push(
                                        ev.t + net.compute_seconds(node),
                                        EventKind::ComputeDone {
                                            node,
                                            round: round + 1,
                                        },
                                    );
                                }
                            }
                            EventKind::MessageArrive { src, dst, msg } => {
                                if let Some(p) = store.remove(&msg) {
                                    mailbox[dst].insert(src, p);
                                }
                            }
                            EventKind::PhaseBarrier { .. } => {}
                        }
                    }
                }
            }
        }

        tele.emit_with(|| Event::RunFinished {
            rounds,
            wall_seconds: t0.elapsed().as_secs_f64(),
            messages: ledger.messages,
            bytes: ledger.bytes,
            wire_bytes: ledger.bytes_on_wire,
            drops: tele.dropped(),
        });
        let finals = w.finals(&nodes);
        Ok(ExecTrace {
            backend: "simnet",
            topology: seq.name.clone(),
            n,
            max_degree: seq.max_degree(),
            run: RunResult {
                label: format!(
                    "{} × {} [simnet {}]",
                    w.label(),
                    seq.name,
                    self.sim.mode.label()
                ),
                records,
            },
            ledger,
            drops,
            trace,
            wall_seconds: t0.elapsed().as_secs_f64(),
            wire_matrix: Vec::new(),
            finals,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::consensus::gaussian_init;
    use crate::exec::{
        quadratic_fixed_targets, AnalyticExecutor, ConsensusWorkload,
        TrainingWorkload,
    };
    use crate::optim::OptimizerKind;
    use crate::simnet::Scenario;
    use crate::topology::{base, baselines, TopologyKind};
    use crate::train::TrainConfig;
    use crate::util::rng::Rng;

    #[test]
    fn ideal_bsp_is_bit_identical_to_analytic() {
        let seq = base::base(12, 2).unwrap();
        let mut rng = Rng::new(3);
        let init = gaussian_init(12, 3, &mut rng);
        let iters = 2 * seq.len();
        let a = AnalyticExecutor::serial()
            .run(&mut ConsensusWorkload::new(init.clone()), &seq, iters)
            .unwrap();
        let s = SimnetExecutor::new(SimConfig::ideal())
            .run(&mut ConsensusWorkload::new(init), &seq, iters)
            .unwrap();
        assert_eq!(a.errors(), s.errors());
        assert_eq!(a.finals, s.finals);
        assert!(s.times().iter().all(|&t| t == 0.0));
        assert_eq!(s.drops, 0);
        let per_sweep: u64 =
            seq.phases.iter().map(|p| p.messages() as u64).sum();
        assert_eq!(s.messages(), 2 * per_sweep);
    }

    #[test]
    fn hostile_async_still_contracts_and_is_seed_deterministic() {
        let seq = base::base(10, 1).unwrap();
        let run = |seed: u64| {
            let mut sim = Scenario::Hostile.config(seed);
            sim.mode = ExecMode::Async;
            sim.record_trace = true;
            let mut rng = Rng::new(5);
            let init = gaussian_init(10, 2, &mut rng);
            SimnetExecutor::new(sim)
                .run(&mut ConsensusWorkload::new(init), &seq, 4 * seq.len())
                .unwrap()
        };
        let a = run(7);
        let b = run(7);
        assert_eq!(a.trace, b.trace, "same seed must replay identically");
        assert_eq!(a.finals, b.finals);
        assert_eq!(a.drops, b.drops);
        assert!(!a.trace.is_empty());
        assert!(a.drops > 0, "hostile scenario must drop messages");
        assert!(a.final_error() < a.errors()[0]);
        let c = run(8);
        assert!(a.trace != c.trace || a.finals != c.finals);
    }

    // ------------------------------------------------------------------
    // Behavior pinned by the removed `simnet::driver` wrappers, folded
    // onto the executor API (the wrappers' one-release window is over).
    // ------------------------------------------------------------------

    #[test]
    fn async_ideal_consensus_converges() {
        let seq = base::base(10, 1).unwrap();
        let mut rng = Rng::new(5);
        let init = gaussian_init(10, 2, &mut rng);
        let mut cfg = SimConfig::ideal();
        cfg.mode = ExecMode::Async;
        let iters = 6 * seq.len();
        let tr = SimnetExecutor::new(cfg)
            .run(&mut ConsensusWorkload::new(init), &seq, iters)
            .unwrap();
        let errors = tr.errors();
        assert_eq!(errors.len(), iters + 1);
        assert!(errors.iter().all(|e| e.is_finite()));
        // Async staleness costs exactness (and speed), not convergence:
        // stale pairwise averages still contract across sweeps.
        assert!(
            tr.final_error() < errors[0] * 0.5,
            "async error {:.3e} vs initial {:.3e}",
            tr.final_error(),
            errors[0]
        );
    }

    #[test]
    fn ideal_bsp_training_reproduces_analytic_exactly() {
        // Zero latency + zero drops + homogeneous compute ⇒ the
        // event-driven BSP engine and the analytic backend walk the same
        // trajectory bit-for-bit (same seed, same rounds), including the
        // D² damping path and gradient tracking's 2-message rounds.
        for optimizer in [
            OptimizerKind::Dsgdm { momentum: 0.9 },
            OptimizerKind::D2,
            OptimizerKind::GradientTracking,
        ] {
            let n = 8;
            let seq = base::base(n, 1).unwrap();
            let cfg = TrainConfig {
                rounds: 30,
                lr: 0.2,
                warmup: 5,
                cosine: true,
                optimizer,
                eval_every: 10,
                threads: 1,
                ..Default::default()
            };
            let (model, data) = quadratic_fixed_targets(n, 4, 11);
            let mut w = TrainingWorkload::new(&model, &cfg, data, &[]);
            let analytic = AnalyticExecutor::new(cfg.cost, cfg.threads)
                .run(&mut w, &seq, cfg.rounds)
                .unwrap();
            let (model, data) = quadratic_fixed_targets(n, 4, 11);
            let mut w = TrainingWorkload::new(&model, &cfg, data, &[]);
            let sim = SimnetExecutor::new(SimConfig::ideal())
                .run(&mut w, &seq, cfg.rounds)
                .unwrap();
            assert_eq!(
                analytic.run.records.len(),
                sim.run.records.len()
            );
            for (a, s) in
                analytic.run.records.iter().zip(&sim.run.records)
            {
                assert_eq!(a.round, s.round);
                assert_eq!(
                    a.train_loss, s.train_loss,
                    "{}: loss diverged at round {}",
                    cfg.optimizer.label(),
                    a.round
                );
                assert_eq!(
                    a.consensus_error.is_nan(),
                    s.consensus_error.is_nan()
                );
                if !a.consensus_error.is_nan() {
                    assert_eq!(a.consensus_error, s.consensus_error);
                }
                // Same physical sends counted, event-by-event.
                assert_eq!(a.cum_messages, s.cum_messages);
                assert_eq!(a.cum_bytes, s.cum_bytes);
            }
            assert_eq!(analytic.finals, sim.finals);
        }
    }

    #[test]
    fn identical_seed_identical_trace_and_params() {
        let run = |seed: u64| {
            let n = 10;
            let seq = base::base(n, 1).unwrap();
            let (model, data) = quadratic_fixed_targets(n, 3, 2);
            let mut sim = Scenario::Hostile.config(seed);
            sim.mode = ExecMode::Async;
            sim.record_trace = true;
            let cfg = TrainConfig {
                rounds: 12,
                lr: 0.2,
                warmup: 0,
                cosine: false,
                optimizer: OptimizerKind::Dsgd,
                eval_every: 0,
                threads: 1,
                ..Default::default()
            };
            let mut w = TrainingWorkload::new(&model, &cfg, data, &[]);
            SimnetExecutor::new(sim)
                .run(&mut w, &seq, cfg.rounds)
                .unwrap()
        };
        let a = run(7);
        let b = run(7);
        assert_eq!(a.trace, b.trace, "same seed must replay identically");
        assert_eq!(a.finals, b.finals);
        assert_eq!(a.drops, b.drops);
        assert!(!a.trace.is_empty());
        let c = run(8);
        assert!(
            a.trace != c.trace || a.finals != c.finals,
            "different seeds should perturb the run"
        );
    }

    #[test]
    fn finite_time_topology_keeps_edge_under_stragglers_and_drops() {
        // The measured version of the paper's claim: under stragglers +
        // drops + rack-heterogeneous links, the Base-(k+1) Graph still
        // reaches consensus in a fraction of the ring's simulated time.
        let n = 24;
        let iters = 60;
        let run = |kind: TopologyKind, sc: Scenario, seed: u64| {
            let seq = kind.build(n, 0).unwrap();
            let cfg = sc.config(seed);
            let mut rng = Rng::new(1);
            let init = gaussian_init(n, 1, &mut rng);
            SimnetExecutor::new(cfg)
                .run(&mut ConsensusWorkload::new(init), &seq, iters)
                .unwrap()
        };

        // Stragglers only (no loss): finite-time consensus survives — the
        // Base-2 Graph is exact after one sweep even on the slow network.
        let base_s =
            run(TopologyKind::Base { m: 2 }, Scenario::Straggler, 42);
        let bt = base_s
            .time_to_reach(1e-15)
            .expect("base-2 stays finite-time under stragglers");
        assert!(bt > 0.0, "straggler network must cost real time");
        let ring_s = run(TopologyKind::Ring, Scenario::Straggler, 42);
        assert!(ring_s.time_to_reach(1e-15).is_none());

        // Stragglers + 10% drops + racks: exactness is gone, but the
        // time-to-accuracy edge survives.
        let base_h =
            run(TopologyKind::Base { m: 2 }, Scenario::Hostile, 42);
        let ring_h = run(TopologyKind::Ring, Scenario::Hostile, 42);
        assert!(base_h.drops > 0, "hostile scenario must drop messages");
        let bh = base_h
            .time_to_reach(1e-3)
            .expect("base-2 reaches 1e-3 despite drops");
        let rh = ring_h.time_to_reach(1e-3).unwrap_or(f64::INFINITY);
        assert!(bh < rh, "base-2 time {bh:.3}s must beat ring ({rh:.3}s)");
        assert!(base_h.final_error() < ring_h.final_error());

        // Reproducible from the seed alone.
        let again =
            run(TopologyKind::Base { m: 2 }, Scenario::Hostile, 42);
        assert_eq!(base_h.errors(), again.errors());
        assert_eq!(base_h.times(), again.times());
        assert_eq!(base_h.drops, again.drops);
    }

    #[test]
    fn per_link_codec_policy_charges_exact_bytes_and_transcodes() {
        use crate::codec::Codec;
        use crate::simnet::CodecPolicy;
        let n = 8;
        let seq = baselines::ring(n);
        let d = 6;
        let mut rng = Rng::new(4);
        let init = gaussian_init(n, d, &mut rng);
        let iters = 6;
        let run = |policy: CodecPolicy| {
            let mut cfg = Scenario::Lan.config(3);
            cfg.codec_policy = policy;
            SimnetExecutor::new(cfg)
                .run(
                    &mut ConsensusWorkload::new(init.clone()),
                    &seq,
                    iters,
                )
                .unwrap()
        };
        let plain = run(CodecPolicy::off());
        let racks = run(CodecPolicy::remote_links(Codec::Bf16, 4));
        // Exact per-link accounting: rack-crossing links carry 2-byte
        // bf16 elements, rack-local links the full 8-byte f64.
        let mut expect = 0u64;
        for r in 0..iters {
            let plan = &seq.phases[r % seq.len()];
            for (dst, src, _w) in plan.directed_edges() {
                expect += if src / 4 != dst / 4 {
                    2 * d as u64
                } else {
                    8 * d as u64
                };
            }
        }
        assert_eq!(racks.ledger.bytes, expect);
        assert!(racks.ledger.bytes < plain.ledger.bytes);
        // Remote links deliver transcoded (lossy) values —
        // deterministically per seed.
        assert_ne!(racks.finals, plain.finals);
        let again = run(CodecPolicy::remote_links(Codec::Bf16, 4));
        assert_eq!(racks.finals, again.finals);
        // rack_size 0 compresses every link: the all-bf16 byte floor.
        let wan = run(CodecPolicy::remote_links(Codec::Bf16, 0));
        assert_eq!(wan.ledger.bytes, plain.ledger.bytes / 4);
        // Async mode takes the same policy path.
        let mut cfg = Scenario::Lan.config(3);
        cfg.mode = ExecMode::Async;
        cfg.codec_policy = CodecPolicy::remote_links(Codec::Bf16, 4);
        let async_tr = SimnetExecutor::new(cfg)
            .run(&mut ConsensusWorkload::new(init.clone()), &seq, iters)
            .unwrap();
        assert_eq!(async_tr.ledger.bytes, expect);
    }

    #[test]
    fn straggler_scenario_gates_the_clock_on_the_slow_nodes() {
        // With a 10× straggler subset, every completed global round costs
        // at least one straggler compute time (both modes wait for the
        // slowest node to have finished its rounds); without stragglers
        // the same iteration count is an order of magnitude cheaper.
        let n = 16;
        let seq = baselines::ring(n);
        let iters = 10;
        let strag = Scenario::Straggler.config(9);
        // ceil(16 · 0.125) = 2 straggler nodes at 10 × 5 ms minimum each.
        let floor = iters as f64
            * strag.compute.mean_seconds
            * strag.compute.straggler_factor;
        let run = |cfg: SimConfig| {
            let mut rng = Rng::new(2);
            let init = gaussian_init(n, 1, &mut rng);
            SimnetExecutor::new(cfg)
                .run(&mut ConsensusWorkload::new(init), &seq, iters)
                .unwrap()
                .sim_seconds()
        };
        for mode in [ExecMode::BulkSynchronous, ExecMode::Async] {
            let mut cfg = strag.clone();
            cfg.mode = mode;
            let t = run(cfg);
            assert!(
                t >= floor,
                "{}: {t:.4}s below straggler floor {floor:.4}s",
                mode.label()
            );
        }
        let t_lan = run(Scenario::Lan.config(9));
        assert!(
            t_lan < floor / 3.0,
            "lan time {t_lan:.4}s should be far below {floor:.4}s"
        );
    }
}
