//! The process-parallel backend: one OS worker process per node shard,
//! behind the same [`Executor`] trait as every in-process backend.
//!
//! This is the first backend where gossip crosses a *real* process
//! boundary — serialized frames over Unix-domain sockets (TCP loopback as
//! the fallback transport) — so Base-(k+1)'s small maximum degree shows
//! up as measured bytes-on-the-wire and wall-clock, not just as an α–β
//! model or intra-process memory traffic.
//!
//! # Architecture
//!
//! ```text
//!  ProcessExecutor (coordinator, this process)
//!    │  re-exec `basegraph --worker <addr> <shard>`  (hidden CLI mode)
//!    ▼
//!  worker 0 ◄──┐                     shard plan (exec/shard.rs):
//!  worker 1 ◄──┼── framed messages   node → shard, contiguous or
//!  …           │   (exec/wire.rs)    degree-balanced
//!  worker k-1 ◄┘
//! ```
//!
//! Workers rebuild the workload from its [`Workload::wire_spec`] (same
//! binary, same deterministic constructors), init all `n` nodes and keep
//! only their shard. Each lock-step round:
//!
//! 1. every worker runs `local_step` + `make_payload` for its nodes;
//! 2. cross-shard payloads are batched into **one bundle frame per
//!    (src shard, dst shard) pair** and routed through the coordinator
//!    (collect-then-forward, which is deadlock-free by construction);
//! 3. workers combine from payload snapshots — intra-shard from memory,
//!    cross-shard from decoded frames — in neighbor-list order;
//! 4. workers ship per-node observation snapshots; the coordinator runs
//!    `observe_wire` centrally, in node order, so metrics accumulate in
//!    exactly the arithmetic order of the in-process backends.
//!
//! The result is **bit-identical** to `AnalyticExecutor` (the equivalence
//! suite pins it at n ∈ {8, 64} for both shipped workloads): everything
//! on the wire is exact bit patterns, schedules are deterministic, and no
//! floating-point reduction is resharded.
//!
//! A worker crash, a truncated frame, a checksum mismatch or a silent
//! hang all surface as clean errors on the coordinator (per-frame read
//! timeout, [`ProcessExecutor::io_timeout`]) — never a deadlock. With a
//! checkpoint policy set ([`ProcessExecutor::ckpt`]), worker death is a
//! *recovery* instead: OBS frames at due round boundaries carry each
//! node's checkpoint blob, the coordinator assembles them into a
//! [`Snapshot`](crate::ckpt::Snapshot), and on failure it kills the
//! remaining workers, respawns every shard with the snapshot's states in
//! the CONFIG frame, and replays forward from that consistent cut —
//! bit-identical to the uninterrupted run on every model column. The
//! listener lives on a shared namespace (temp-dir UDS path / loopback
//! port), so every worker must echo a per-run handshake token (passed
//! through the environment, not argv) before it is seated.
//!
//! # Example
//!
//! ```no_run
//! use basegraph::comm::CostModel;
//! use basegraph::consensus::gaussian_init;
//! use basegraph::exec::{ConsensusWorkload, Executor, ProcessExecutor};
//! use basegraph::topology::TopologyKind;
//! use basegraph::util::rng::Rng;
//!
//! let seq = TopologyKind::Base { m: 4 }.build(64, 0).unwrap();
//! let mut rng = Rng::new(7);
//! let init = gaussian_init(64, 8, &mut rng);
//! let exec = ProcessExecutor::new(CostModel::default(), 2);
//! let tr = exec
//!     .run(&mut ConsensusWorkload::new(init), &seq, 2 * seq.len())
//!     .unwrap();
//! assert_eq!(tr.backend, "process");
//! assert!(tr.ledger.bytes_on_wire > 0, "real frames crossed sockets");
//! ```
//! (`no_run` here only because doc-tests execute from a harness binary;
//! spawning runs live in `tests/exec_equivalence.rs` and the CLI smoke.)

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use super::scratch::AvailTable;
use super::shard::{cross_shard_sources, ShardPlan};
use super::wire::{
    self, read_frame, read_frame_into, write_frame, ByteReader, ByteWriter,
};
use super::workload::{
    decode_wire_spec, quadratic_fixed_targets, DecodedSpec, TrainSpec,
};
use super::{
    ConsensusWorkload, ExecTrace, Executor, TrainingWorkload, Workload,
};
use crate::ckpt::{CkptConfig, Snapshot};
use crate::comm::{CommLedger, CostModel};
use crate::metrics::{RoundRecord, RunResult};
use crate::repro::common::{
    classification_workload, partitioned_node_data, Engine,
};
use crate::simnet::event::Trace;
use crate::telemetry::{Event, Telemetry};
use crate::topology::resequence::{embedded_base, MIN_LIVE};
use crate::topology::GraphSequence;

// Frame kinds of the coordinator ↔ worker protocol.
const FRAME_HELLO: u8 = 1;
const FRAME_CONFIG: u8 = 2;
const FRAME_BUNDLE: u8 = 3;
const FRAME_OBS: u8 = 4;
const FRAME_FINALS: u8 = 5;
const FRAME_ERROR: u8 = 6;
const FRAME_SHUTDOWN: u8 = 7;

/// Observation-frame round marker for the pre-round-0 snapshot.
const INIT_ROUND: u32 = u32::MAX;

/// Env var carrying the per-run handshake token to workers (environment
/// blocks are owner-readable only, unlike argv).
const TOKEN_ENV: &str = "BASEGRAPH_WORKER_TOKEN";

static SOCKET_COUNTER: AtomicU64 = AtomicU64::new(0);

/// A per-run handshake token: the listener lives on a shared namespace
/// (a temp-dir UDS path or a loopback port), so an unrelated local
/// process could dial it. Workers must echo this token in their HELLO
/// or the coordinator drops them — closing both the shard-squatting and
/// the spec-disclosure hole. splitmix64 over wall clock, pid and a
/// process-local counter; unpredictability against a *determined* local
/// attacker is explicitly not the bar (same-UID processes can do worse).
fn handshake_token() -> u64 {
    use std::time::{SystemTime, UNIX_EPOCH};
    let nanos = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let seed = nanos
        ^ ((std::process::id() as u64) << 32)
        ^ SOCKET_COUNTER.fetch_add(1, Ordering::Relaxed);
    let mut z = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

// ---------------------------------------------------------------------------
// Transport: UDS with TCP-loopback fallback
// ---------------------------------------------------------------------------

/// One coordinator↔worker connection, transport-erased.
enum Conn {
    #[cfg(unix)]
    Unix(UnixStream),
    Tcp(TcpStream),
}

impl Conn {
    fn set_read_timeout(&self, d: Option<Duration>) -> std::io::Result<()> {
        match self {
            #[cfg(unix)]
            Conn::Unix(s) => s.set_read_timeout(d),
            Conn::Tcp(s) => s.set_read_timeout(d),
        }
    }

    fn set_nonblocking(&self, nb: bool) -> std::io::Result<()> {
        match self {
            #[cfg(unix)]
            Conn::Unix(s) => s.set_nonblocking(nb),
            Conn::Tcp(s) => s.set_nonblocking(nb),
        }
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            #[cfg(unix)]
            Conn::Unix(s) => s.read(buf),
            Conn::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            #[cfg(unix)]
            Conn::Unix(s) => s.write(buf),
            Conn::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            #[cfg(unix)]
            Conn::Unix(s) => s.flush(),
            Conn::Tcp(s) => s.flush(),
        }
    }
}

/// The coordinator's listening socket; `Drop` removes a UDS path.
enum Listener {
    #[cfg(unix)]
    Unix(UnixListener, PathBuf),
    Tcp(TcpListener),
}

impl Listener {
    /// Bind a fresh listener and return it with the address string
    /// workers dial (`uds:<path>` or `tcp:<ip>:<port>`).
    fn bind(force_tcp: bool) -> Result<(Listener, String), String> {
        #[cfg(unix)]
        if !force_tcp {
            let path = std::env::temp_dir().join(format!(
                "basegraph-{}-{}.sock",
                std::process::id(),
                SOCKET_COUNTER.fetch_add(1, Ordering::Relaxed)
            ));
            let _ = std::fs::remove_file(&path);
            if let Ok(l) = UnixListener::bind(&path) {
                l.set_nonblocking(true)
                    .map_err(|e| format!("uds nonblocking: {e}"))?;
                let addr = format!("uds:{}", path.display());
                return Ok((Listener::Unix(l, path), addr));
            }
            // Fall through to TCP loopback.
        }
        let l = TcpListener::bind(("127.0.0.1", 0))
            .map_err(|e| format!("bind tcp loopback: {e}"))?;
        l.set_nonblocking(true)
            .map_err(|e| format!("tcp nonblocking: {e}"))?;
        let addr = l
            .local_addr()
            .map_err(|e| format!("tcp local_addr: {e}"))?;
        Ok((Listener::Tcp(l), format!("tcp:{addr}")))
    }

    fn accept(&self) -> std::io::Result<Conn> {
        match self {
            #[cfg(unix)]
            Listener::Unix(l, _) => l.accept().map(|(s, _)| Conn::Unix(s)),
            Listener::Tcp(l) => l.accept().map(|(s, _)| Conn::Tcp(s)),
        }
    }
}

impl Drop for Listener {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Listener::Unix(_, path) = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// Worker side: dial the coordinator's address string.
fn connect(addr: &str) -> Result<Conn, String> {
    if let Some(path) = addr.strip_prefix("uds:") {
        #[cfg(unix)]
        {
            return UnixStream::connect(path)
                .map(Conn::Unix)
                .map_err(|e| format!("connect {addr}: {e}"));
        }
        #[cfg(not(unix))]
        return Err(format!("uds transport unavailable: {path}"));
    }
    if let Some(sock) = addr.strip_prefix("tcp:") {
        return TcpStream::connect(sock)
            .map(Conn::Tcp)
            .map_err(|e| format!("connect {addr}: {e}"));
    }
    Err(format!("bad coordinator address {addr:?}"))
}

// ---------------------------------------------------------------------------
// Framing helpers with byte accounting
// ---------------------------------------------------------------------------

fn send(
    conn: &mut Conn,
    kind: u8,
    payload: &[u8],
    wire_bytes: &mut u64,
) -> Result<(), String> {
    *wire_bytes += write_frame(conn, kind, payload)?;
    Ok(())
}

/// Read one frame; a worker-reported `ERROR` frame propagates as `Err`.
fn recv(
    conn: &mut Conn,
    wire_bytes: &mut u64,
) -> Result<(u8, Vec<u8>), String> {
    let mut payload = Vec::new();
    let kind = recv_into(conn, &mut payload, wire_bytes)?;
    Ok((kind, payload))
}

/// [`recv`] into a caller-owned buffer, reusing its allocation — the
/// per-round receive path on both sides of the protocol.
fn recv_into(
    conn: &mut Conn,
    buf: &mut Vec<u8>,
    wire_bytes: &mut u64,
) -> Result<u8, String> {
    let (kind, bytes) = read_frame_into(conn, buf)?;
    *wire_bytes += bytes;
    if kind == FRAME_ERROR {
        return Err(format!(
            "worker reported: {}",
            String::from_utf8_lossy(buf)
        ));
    }
    Ok(kind)
}

// ---------------------------------------------------------------------------
// Coordinator
// ---------------------------------------------------------------------------

/// Kills any still-running worker on scope exit (error paths); the happy
/// path waits for them after the shutdown frames.
struct WorkerProcs {
    children: Vec<Child>,
}

impl Drop for WorkerProcs {
    fn drop(&mut self) {
        for c in &mut self.children {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

/// Parameters of heartbeat-timeout eviction ([`ProcessExecutor::evict`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EvictSpec {
    /// Base-(k+1) maximum degree used to resequence the survivors.
    pub k: usize,
}

/// One OS process per node shard behind the [`Executor`] trait: re-execs
/// this binary in a hidden `--worker` mode and runs lock-step rounds over
/// length-prefixed, checksummed socket frames (see the module docs).
///
/// The α–β `cost` model feeds the same simulated-seconds column as the
/// analytic backend; the *measured* columns are `ExecTrace::wall_seconds`
/// and `CommLedger::bytes_on_wire`.
#[derive(Debug, Clone)]
pub struct ProcessExecutor {
    pub cost: CostModel,
    /// Worker-process count (clamped to `[1, n]` at run time).
    pub shards: usize,
    /// Degree-balanced sharding instead of index-contiguous.
    pub balanced: bool,
    /// Per-frame coordinator read timeout: a hung or dead worker becomes
    /// a clean error, never a stuck run.
    pub io_timeout: Duration,
    /// Force the TCP-loopback transport (exercises the UDS fallback).
    pub force_tcp: bool,
    /// Explicit path to the `basegraph` binary for worker re-exec; when
    /// unset, resolution tries `$BASEGRAPH_BIN`, then the current
    /// executable, then its near ancestors (covers `target/*/deps` test
    /// binaries and `target/*/examples`).
    pub worker_bin: Option<PathBuf>,
    /// Fault injection for the crash-path tests: `(shard, round)` at
    /// which that worker aborts at the round boundary, without a goodbye
    /// frame.
    pub fault_crash: Option<(usize, usize)>,
    /// Fault injection *mid-round*: `(shard, round)` at which that worker
    /// aborts after sending its payload bundles but before receiving its
    /// neighbors' — the worst consistent-cut violation a crash can make.
    pub fault_crash_mid: Option<(usize, usize)>,
    /// Checkpoint/resume configuration. With a policy set, worker death
    /// becomes a *recovery*: the coordinator respawns the workers from
    /// the last round-boundary snapshot and replays forward (see
    /// [`ProcessExecutor::max_respawns`]); without one it stays a clean
    /// abort.
    pub ckpt: CkptConfig,
    /// How many crash-recovery respawns one run may use before the
    /// failure propagates as an error.
    pub max_respawns: usize,
    /// Heartbeat eviction (`--churn-evict`): on worker death with a
    /// round-boundary snapshot available and the dead shard attributed,
    /// that shard's live nodes are *evicted* instead of replayed — the
    /// embedded Base-(k+1) sequence is rebuilt over the survivors
    /// (rotation-aligned at the snapshot round), every shard respawns
    /// at the next epoch, and the run resumes from the same consistent
    /// cut. The evicted shard respawns too: its nodes carry on as
    /// isolated ghosts (identity rows), exactly like a scheduled leave
    /// at that boundary. Emits `node_left` (reason `"evicted"`) and
    /// `roster_resequenced` telemetry.
    pub evict: Option<EvictSpec>,
    /// Live-run telemetry. The coordinator is the only emitter (workers
    /// stay mute): besides the shared run/round/checkpoint events it
    /// reports worker lifecycle (spawn pid, death, respawn), one
    /// `shard_bundle` per routed cross-shard bundle (measured bytes of
    /// both hops + frame round-trip latency) and per-shard heartbeat
    /// ages — all from data it already holds while routing.
    pub tele: Telemetry,
}

impl ProcessExecutor {
    pub fn new(cost: CostModel, shards: usize) -> Self {
        ProcessExecutor {
            cost,
            shards,
            balanced: false,
            io_timeout: Duration::from_secs(120),
            force_tcp: false,
            worker_bin: None,
            fault_crash: None,
            fault_crash_mid: None,
            ckpt: CkptConfig::default(),
            max_respawns: 2,
            evict: None,
            tele: Telemetry::off(),
        }
    }

    pub fn with_worker_bin(mut self, bin: impl Into<PathBuf>) -> Self {
        self.worker_bin = Some(bin.into());
        self
    }

    pub fn with_balanced(mut self, balanced: bool) -> Self {
        self.balanced = balanced;
        self
    }

    fn resolve_worker_bin(&self) -> Result<PathBuf, String> {
        if let Some(p) = &self.worker_bin {
            return Ok(p.clone());
        }
        if let Ok(p) = std::env::var("BASEGRAPH_BIN") {
            if !p.is_empty() {
                return Ok(PathBuf::from(p));
            }
        }
        let exe = std::env::current_exe()
            .map_err(|e| format!("current_exe: {e}"))?;
        if exe.file_stem().map(|s| s == "basegraph").unwrap_or(false) {
            return Ok(exe);
        }
        for dir in exe.ancestors().skip(1).take(3) {
            let cand = dir.join("basegraph");
            if cand.is_file() {
                return Ok(cand);
            }
        }
        Err("cannot locate the basegraph binary for --worker re-exec; \
             set ProcessExecutor::worker_bin (in tests: \
             env!(\"CARGO_BIN_EXE_basegraph\")) or $BASEGRAPH_BIN"
            .into())
    }

    /// Spawn workers and accept their handshakes. Early worker death is
    /// detected while polling, so a bad binary fails fast instead of
    /// eating the whole timeout.
    fn accept_workers(
        &self,
        listener: &Listener,
        procs: &mut WorkerProcs,
        k: usize,
        token: u64,
        wire_bytes: &mut u64,
        culprit: &mut Option<usize>,
    ) -> Result<Vec<Conn>, String> {
        let mut slots: Vec<Option<Conn>> = (0..k).map(|_| None).collect();
        let deadline = Instant::now() + self.io_timeout;
        let mut accepted = 0usize;
        while accepted < k {
            match listener.accept() {
                Ok(conn) => {
                    conn.set_nonblocking(false)
                        .map_err(|e| format!("worker socket: {e}"))?;
                    conn.set_read_timeout(Some(self.io_timeout))
                        .map_err(|e| format!("worker socket: {e}"))?;
                    let mut conn = conn;
                    let (kind, payload) = recv(&mut conn, wire_bytes)
                        .map_err(|e| format!("worker handshake: {e}"))?;
                    if kind != FRAME_HELLO {
                        return Err(format!(
                            "worker handshake: expected hello, got frame \
                             kind {kind}"
                        ));
                    }
                    let mut r = ByteReader::new(&payload);
                    let s = r.get_u32()? as usize;
                    let got_token = r.get_u64()?;
                    r.expect_end()?;
                    if got_token != token {
                        return Err(
                            "worker handshake: wrong run token — a \
                             foreign process dialed the worker socket"
                                .into(),
                        );
                    }
                    if s >= k || slots[s].is_some() {
                        return Err(format!(
                            "worker handshake: bad or duplicate shard {s}"
                        ));
                    }
                    slots[s] = Some(conn);
                    accepted += 1;
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    for (s, c) in procs.children.iter_mut().enumerate() {
                        if let Ok(Some(status)) = c.try_wait() {
                            *culprit = Some(s);
                            return Err(format!(
                                "worker {s} exited during handshake \
                                 ({status})"
                            ));
                        }
                    }
                    if Instant::now() > deadline {
                        return Err(format!(
                            "timed out after {:?} waiting for {} worker \
                             handshake(s)",
                            self.io_timeout,
                            k - accepted
                        ));
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e) => return Err(format!("accept worker: {e}")),
            }
        }
        Ok(slots.into_iter().map(|c| c.expect("accepted")).collect())
    }

    /// One spawn → configure → lock-step → finals attempt over a fresh
    /// set of worker processes, starting at `last_snap`'s round (0 when
    /// none). Shared accounting (`ledger`, `records`, `wire_bytes`) is
    /// mutated in place; on `Err` the caller restores the model columns
    /// from `last_snap` before retrying — `wire_bytes` deliberately keeps
    /// the failed attempt's traffic, it is a *measured* column. Snapshots
    /// taken at due round boundaries are written through the policy (when
    /// one is set) and parked in `last_snap` for in-run recovery.
    #[allow(clippy::too_many_arguments)] // internal engine; sole caller is run()
    fn run_attempt<W: Workload>(
        &self,
        w: &W,
        seq: &GraphSequence,
        rounds: usize,
        spec: &[u8],
        splan: &ShardPlan,
        cross: &[Vec<Vec<Vec<usize>>>],
        faults: (Option<(usize, usize)>, Option<(usize, usize)>),
        ckpt_every: usize,
        ckpt_force: Option<usize>,
        epoch: u32,
        roster: &Option<Vec<u32>>,
        t0: Instant,
        wire_bytes: &mut u64,
        pair_bytes: &mut [u64],
        ledger: &mut CommLedger,
        records: &mut Vec<RoundRecord>,
        last_snap: &mut Option<Snapshot>,
        culprit: &mut Option<usize>,
    ) -> Result<Vec<Vec<f64>>, String> {
        let n = seq.n;
        let k = self.shards.clamp(1, n);
        let start_round = last_snap.as_ref().map(|s| s.round).unwrap_or(0);
        let (fault_crash, fault_crash_mid) = faults;
        *culprit = None;

        // 1. Listen, spawn, handshake.
        let (listener, addr) = Listener::bind(self.force_tcp)?;
        let bin = self.resolve_worker_bin()?;
        let token = handshake_token();
        let mut procs = WorkerProcs { children: Vec::with_capacity(k) };
        for s in 0..k {
            let child = Command::new(&bin)
                .arg("--worker")
                .arg(&addr)
                .arg(s.to_string())
                .env(TOKEN_ENV, format!("{token:016x}"))
                .stdin(Stdio::null())
                .spawn()
                .map_err(|e| {
                    format!("spawn worker {s} ({}): {e}", bin.display())
                })?;
            self.tele.emit_with(|| Event::WorkerSpawned {
                shard: s,
                nodes: splan.owner.iter().filter(|&&o| o == s).count(),
                pid: child.id() as u64,
            });
            procs.children.push(child);
        }
        let mut conns = self.accept_workers(
            &listener,
            &mut procs,
            k,
            token,
            wire_bytes,
            culprit,
        )?;

        // 2. Configuration: topology, shard map, workload spec, faults,
        //    checkpoint cadence, and — when resuming — the shard's node
        //    states from the snapshot.
        let mut sw = ByteWriter::new();
        wire::encode_seq(seq, &mut sw);
        let seq_bytes = sw.finish();
        for (s, conn) in conns.iter_mut().enumerate() {
            let mut cw = ByteWriter::new();
            cw.put_usize(n);
            cw.put_usize(rounds);
            cw.put_usize(k);
            cw.put_usize(s);
            cw.put_u32(epoch);
            for &o in &splan.owner {
                cw.put_u32(o as u32);
            }
            cw.put_bytes(&seq_bytes);
            cw.put_bytes(spec);
            let crash = match fault_crash {
                Some((fs, r)) if fs == s => r as u64,
                _ => u64::MAX,
            };
            cw.put_u64(crash);
            let crash_mid = match fault_crash_mid {
                Some((fs, r)) if fs == s => r as u64,
                _ => u64::MAX,
            };
            cw.put_u64(crash_mid);
            cw.put_u64(ckpt_every as u64);
            cw.put_u64(ckpt_force.map(|r| r as u64).unwrap_or(u64::MAX));
            cw.put_u64(start_round as u64);
            match last_snap.as_ref().filter(|_| start_round > 0) {
                Some(snap) => {
                    let members =
                        (0..n).filter(|&i| splan.owner[i] == s);
                    cw.put_usize(members.clone().count());
                    for i in members {
                        cw.put_u32(i as u32);
                        cw.put_bytes(&snap.nodes[i]);
                    }
                }
                None => cw.put_usize(0),
            }
            // Live roster (0 = full): the worker validates the subset;
            // membership itself is enforced by the plan's identity rows
            // (ghost nodes simply have no neighbors).
            match roster {
                Some(ids) => {
                    cw.put_usize(ids.len());
                    for &i in ids {
                        cw.put_u32(i);
                    }
                }
                None => cw.put_usize(0),
            }
            send(conn, FRAME_CONFIG, &cw.finish(), wire_bytes)
                .map_err(|e| format!("configure shard {s}: {e}"))?;
        }

        let (n_slots, slot_bytes) = w.comm_shape();
        // Reused across rounds: the observation assembly buffers and the
        // bundle forward buffers (one per in-flight cross-shard pair).
        let mut obs = ObsBufs::new(n);
        let mut fwd_bufs: Vec<Vec<u8>> = Vec::new();
        let mut fwd_dst: Vec<usize> = Vec::new();
        // Per-bundle source shard and inbound-hop bytes, parallel to
        // `fwd_dst` — feeds the (src,dst) wire matrix and telemetry.
        let mut fwd_src: Vec<usize> = Vec::new();
        let mut fwd_in: Vec<u64> = Vec::new();
        // When the coordinator last heard a frame from each shard
        // (telemetry heartbeat ages; measured, never a model column).
        let mut last_heard: Vec<Instant> = vec![Instant::now(); k];

        // 3. Pre-round-0 snapshot (consensus records its initial error).
        //    A resumed run's round-0 record is part of the restored
        //    history — never re-taken.
        if start_round == 0 {
            obs.collect(
                &mut conns,
                INIT_ROUND,
                epoch,
                &splan.owner,
                false,
                wire_bytes,
                culprit,
            )?;
            if let Some(mut rec) = w.initial_record_wire(&obs.slots)? {
                rec.wall_seconds = t0.elapsed().as_secs_f64();
                records.push(rec);
            }
        }

        // 4. Lock-step rounds: collect bundles → forward → observe.
        for r in start_round..rounds {
            let round_t0 = Instant::now();
            let pidx = r % seq.len();
            let plan = seq.phase(r);
            let xs = &cross[pidx];

            fwd_dst.clear();
            fwd_src.clear();
            fwd_in.clear();
            for s in 0..k {
                let expected = (0..k)
                    .filter(|&t| t != s && !xs[s][t].is_empty())
                    .count();
                for _ in 0..expected {
                    if fwd_dst.len() == fwd_bufs.len() {
                        fwd_bufs.push(Vec::new());
                    }
                    let buf = &mut fwd_bufs[fwd_dst.len()];
                    let before = *wire_bytes;
                    let kind = recv_into(&mut conns[s], buf, wire_bytes)
                        .map_err(|e| {
                            *culprit = Some(s);
                            format!("round {r}: shard {s}: {e}")
                        })?;
                    if kind != FRAME_BUNDLE {
                        return Err(format!(
                            "round {r}: shard {s}: expected a payload \
                             bundle, got frame kind {kind}"
                        ));
                    }
                    let mut br = ByteReader::new(buf);
                    let fe = br.get_u32()?;
                    if fe != epoch {
                        *culprit = Some(s);
                        return Err(format!(
                            "round {r}: shard {s}: stale-epoch bundle \
                             (frame epoch {fe}, coordinator at {epoch})"
                        ));
                    }
                    let fr = br.get_u32()? as usize;
                    let fsrc = br.get_u32()? as usize;
                    let fdst = br.get_u32()? as usize;
                    if fr != r || fsrc != s || fdst >= k || fdst == s {
                        return Err(format!(
                            "round {r}: shard {s}: bundle header out of \
                             sync (round {fr}, {fsrc} → {fdst})"
                        ));
                    }
                    let in_bytes = *wire_bytes - before;
                    pair_bytes[s * k + fdst] += in_bytes;
                    last_heard[s] = Instant::now();
                    fwd_dst.push(fdst);
                    fwd_src.push(s);
                    fwd_in.push(in_bytes);
                }
            }
            for (i, (payload, &dst)) in
                fwd_bufs.iter().zip(&fwd_dst).enumerate()
            {
                let before = *wire_bytes;
                send(&mut conns[dst], FRAME_BUNDLE, payload, wire_bytes)
                    .map_err(|e| {
                        *culprit = Some(dst);
                        format!("round {r}: forward to shard {dst}: {e}")
                    })?;
                let out_bytes = *wire_bytes - before;
                let src = fwd_src[i];
                pair_bytes[src * k + dst] += out_bytes;
                self.tele.emit_with(|| Event::ShardBundle {
                    round: r,
                    src,
                    dst,
                    bytes: fwd_in[i] + out_bytes,
                    rtt_seconds: round_t0.elapsed().as_secs_f64(),
                });
            }

            let eval = w.is_eval(r, rounds);
            let due = (ckpt_every > 0 && (r + 1) % ckpt_every == 0)
                || ckpt_force == Some(r + 1);
            // Heartbeat ages are sampled just before the blocking OBS
            // collect — the point in the round where a silent worker
            // would stall the coordinator. Gated so the off path never
            // touches the clock vector.
            let ages: Vec<f64> = if self.tele.is_on() {
                let now = Instant::now();
                last_heard
                    .iter()
                    .map(|t| now.duration_since(*t).as_secs_f64())
                    .collect()
            } else {
                Vec::new()
            };
            obs.collect(
                &mut conns,
                r as u32,
                epoch,
                &splan.owner,
                due,
                wire_bytes,
                culprit,
            )
            .map_err(|e| format!("round {r}: {e}"))?;
            for (s, last) in last_heard.iter_mut().enumerate() {
                *last = Instant::now();
                self.tele.emit_with(|| Event::WorkerHeartbeat {
                    round: r,
                    shard: s,
                    heartbeat_age_seconds: ages[s],
                });
            }

            // α–β accounting — identical to the analytic backend, so the
            // simulated-seconds column stays comparable across backends;
            // the measured counterpart is bytes_on_wire below.
            for _ in 0..n_slots {
                ledger.record_round_bytes(plan, slot_bytes, &self.cost);
            }
            ledger.bytes_on_wire = *wire_bytes;
            let mut rec = w
                .observe_wire(&obs.slots, r, eval)
                .map_err(|e| format!("round {r}: {e}"))?;
            rec.cum_messages = ledger.messages;
            rec.cum_bytes = ledger.bytes;
            rec.cum_wire_bytes = ledger.bytes_on_wire;
            rec.sim_seconds = ledger.sim_seconds;
            rec.wall_seconds = t0.elapsed().as_secs_f64();
            records.push(rec);
            let committed = records.last().expect("pushed above");
            self.tele.emit_with(|| Event::round(committed));

            // 5. Round-boundary snapshot, when due: assembled from the
            //    OBS frames' state sections, persisted through the
            //    policy, parked in memory for in-run crash recovery.
            if due {
                let snap = Snapshot {
                    topology: seq.name.clone(),
                    n,
                    round: r + 1,
                    nodes: obs.states.clone(),
                    ledger: ledger.clone(),
                    records: records.clone(),
                    clock: 0.0,
                    rng: None,
                    roster: roster.clone(),
                };
                if let Some(pol) = self.ckpt.policy.as_ref() {
                    let path = pol.save(&snap)?;
                    self.tele.emit_with(|| Event::CheckpointWritten {
                        round: r + 1,
                        path: path.display().to_string(),
                    });
                }
                *last_snap = Some(snap);
            }
        }

        // 6. Finals, shutdown, reap.
        let mut fin: Vec<Option<Vec<u8>>> = vec![None; n];
        for (s, conn) in conns.iter_mut().enumerate() {
            let (kind, payload) = recv(conn, wire_bytes).map_err(|e| {
                *culprit = Some(s);
                format!("finals: shard {s}: {e}")
            })?;
            if kind != FRAME_FINALS {
                return Err(format!(
                    "finals: shard {s}: got frame kind {kind}"
                ));
            }
            let mut fr = ByteReader::new(&payload);
            let count = fr.get_usize()?;
            for _ in 0..count {
                let node = fr.get_u32()? as usize;
                if node >= n || splan.owner[node] != s {
                    return Err(format!(
                        "finals: shard {s}: foreign node {node}"
                    ));
                }
                fin[node] = Some(fr.get_bytes()?.to_vec());
            }
            fr.expect_end()?;
        }
        let fin: Vec<Vec<u8>> = fin
            .into_iter()
            .enumerate()
            .map(|(i, o)| {
                o.ok_or_else(|| format!("no final state for node {i}"))
            })
            .collect::<Result<_, String>>()?;
        let finals = w.finals_wire(&fin)?;
        for (s, conn) in conns.iter_mut().enumerate() {
            send(conn, FRAME_SHUTDOWN, &[], wire_bytes)
                .map_err(|e| format!("shutdown shard {s}: {e}"))?;
        }
        drop(conns);
        for c in &mut procs.children {
            let _ = c.wait();
        }
        procs.children.clear();
        Ok(finals)
    }
}

/// Per-round observation assembly state, reused across rounds: one
/// snapshot buffer per node (written in place), the per-round presence
/// flags, and the frame receive buffer.
struct ObsBufs {
    /// Per-node snapshot blobs, in node order; valid after a successful
    /// [`ObsBufs::collect`] until the next one overwrites them.
    slots: Vec<Vec<u8>>,
    /// Per-node checkpoint blobs ([`Workload::node_ckpt`] form), filled
    /// only by collects that expect the OBS frames' state section — i.e.
    /// at checkpoint-due round boundaries.
    states: Vec<Vec<u8>>,
    seen: Vec<bool>,
    frame: Vec<u8>,
}

impl ObsBufs {
    fn new(n: usize) -> Self {
        ObsBufs {
            slots: vec![Vec::new(); n],
            states: vec![Vec::new(); n],
            seen: vec![false; n],
            frame: Vec::new(),
        }
    }

    /// Read one OBS frame from every shard and assemble per-node snapshot
    /// blobs in node order, reusing every buffer. `expect_states` must
    /// match the workers' checkpoint cadence: both sides derive it from
    /// the same `(r + 1) % every == 0 || force_at == r + 1` rule, so a
    /// mismatch is a desync. Frames from another worker generation
    /// (`epoch`) are rejected as stale; `culprit` records the shard a
    /// failure is attributable to, feeding heartbeat eviction.
    #[allow(clippy::too_many_arguments)] // frame codec; two call sites
    fn collect(
        &mut self,
        conns: &mut [Conn],
        marker: u32,
        epoch: u32,
        owner: &[usize],
        expect_states: bool,
        wire_bytes: &mut u64,
        culprit: &mut Option<usize>,
    ) -> Result<(), String> {
        let n = self.slots.len();
        self.seen.fill(false);
        for (s, conn) in conns.iter_mut().enumerate() {
            let kind = recv_into(conn, &mut self.frame, wire_bytes)
                .map_err(|e| {
                    *culprit = Some(s);
                    format!("shard {s}: {e}")
                })?;
            if kind != FRAME_OBS {
                return Err(format!(
                    "shard {s}: expected observation frame, got kind {kind}"
                ));
            }
            let mut r = ByteReader::new(&self.frame);
            let fe = r.get_u32()?;
            if fe != epoch {
                *culprit = Some(s);
                return Err(format!(
                    "shard {s}: stale-epoch observation (frame epoch \
                     {fe}, coordinator at {epoch})"
                ));
            }
            let got = r.get_u32()?;
            if got != marker {
                return Err(format!(
                    "shard {s}: observation out of sync (marker {got} vs \
                     {marker})"
                ));
            }
            let count = r.get_usize()?;
            for _ in 0..count {
                let node = r.get_u32()? as usize;
                if node >= n || owner[node] != s {
                    return Err(format!(
                        "shard {s}: observation for foreign node {node}"
                    ));
                }
                let bytes = r.get_bytes()?;
                self.slots[node].clear();
                self.slots[node].extend_from_slice(bytes);
                self.seen[node] = true;
            }
            let has_states = r.get_u8()? != 0;
            if has_states != expect_states {
                return Err(format!(
                    "shard {s}: checkpoint-state section {} when the \
                     coordinator expected the opposite — cadence desync",
                    if has_states { "present" } else { "absent" }
                ));
            }
            if has_states {
                let count = r.get_usize()?;
                for _ in 0..count {
                    let node = r.get_u32()? as usize;
                    if node >= n || owner[node] != s {
                        return Err(format!(
                            "shard {s}: checkpoint state for foreign node \
                             {node}"
                        ));
                    }
                    let bytes = r.get_bytes()?;
                    self.states[node].clear();
                    self.states[node].extend_from_slice(bytes);
                }
            }
            r.expect_end()?;
        }
        if let Some(i) = self.seen.iter().position(|&x| !x) {
            return Err(format!("no observation arrived for node {i}"));
        }
        Ok(())
    }
}

impl Executor for ProcessExecutor {
    fn backend(&self) -> &'static str {
        "process"
    }

    fn run<W: Workload>(
        &self,
        w: &mut W,
        seq: &GraphSequence,
        rounds: usize,
    ) -> Result<ExecTrace, String> {
        let n = seq.n;
        if n == 0 {
            return Err("process executor needs n >= 1".into());
        }
        if rounds > 0 && seq.is_empty() {
            return Err(
                "process executor needs a non-empty phase sequence".into()
            );
        }
        let spec = w.wire_spec().ok_or_else(|| {
            format!(
                "workload {:?} has no wire form — the process backend can \
                 only run workloads whose spec a worker can rebuild \
                 (ConsensusWorkload, or TrainingWorkload::with_wire)",
                w.label()
            )
        })?;
        let k = self.shards.clamp(1, n);
        let splan = if self.balanced {
            ShardPlan::degree_balanced(seq, k)
        } else {
            ShardPlan::contiguous(n, k)
        };
        let t0 = Instant::now();
        // Per-phase cross-shard batches (what crosses which boundary).
        let cross: Vec<Vec<Vec<Vec<usize>>>> = seq
            .phases
            .iter()
            .map(|p| cross_shard_sources(p, &splan.owner, k))
            .collect();

        // Resume from disk, when configured. `bytes_on_wire` is a
        // *measured* column: it continues from the snapshot's count (the
        // interrupted run's post-snapshot traffic died with its
        // coordinator), so a resumed trace reports real bytes moved, not
        // the uninterrupted run's number — the equivalence pins compare
        // model columns only.
        let mut ledger = CommLedger::default();
        let mut records: Vec<RoundRecord> = Vec::with_capacity(rounds + 1);
        let mut wire_bytes = 0u64;
        let mut last_snap = self.ckpt.load_resume(n, &seq.name, rounds)?;
        if let Some(snap) = &last_snap {
            ledger = snap.ledger.clone();
            records = snap.records.clone();
            wire_bytes = snap.ledger.bytes_on_wire;
        }
        let ckpt_every = self
            .ckpt
            .policy
            .as_ref()
            .map(|p| p.every_n_rounds)
            .unwrap_or(0);
        let ckpt_force =
            self.ckpt.policy.as_ref().and_then(|p| p.force_at);
        // Measured wire bytes per (src, dst) shard pair, flat k×k. Counts
        // both hops of every routed bundle and survives respawns (like
        // `wire_bytes`: real traffic, including the attempts that died).
        let mut pair_bytes = vec![0u64; k * k];
        self.tele.emit_with(|| Event::RunStarted {
            label: w.label(),
            backend: "process",
            topology: seq.name.clone(),
            n,
            rounds,
            start_round: last_snap.as_ref().map(|s| s.round).unwrap_or(0),
        });

        // Crash recovery: every attempt runs on fresh worker processes;
        // a failed attempt that left a round-boundary snapshot is
        // replayed from it (all shards respawn — survivors cannot be
        // rewound mid-round, so the whole group restarts from the same
        // consistent cut). Fault injections fire once, then clear, which
        // is exactly what makes the fault tests *recovery* tests.
        let w: &W = w;
        let mut faults = (self.fault_crash, self.fault_crash_mid);
        let mut respawns_left = self.max_respawns;
        // Epoch fencing state: every (re)spawned worker generation gets
        // the next epoch, and frames stamped with an older one are
        // rejected as stale on both sides of the protocol. Heartbeat
        // eviction may additionally swap in a resequenced topology and
        // a reduced roster between attempts.
        let mut epoch: u32 = 0;
        let mut cur_roster: Option<Vec<u32>> = self.ckpt.roster.clone();
        let mut cur_seq: Option<GraphSequence> = None;
        let mut cross = cross;
        let mut culprit: Option<usize> = None;
        loop {
            let sref = cur_seq.as_ref().unwrap_or(seq);
            match self.run_attempt(
                w,
                sref,
                rounds,
                &spec,
                &splan,
                &cross,
                faults,
                ckpt_every,
                ckpt_force,
                epoch,
                &cur_roster,
                t0,
                &mut wire_bytes,
                &mut pair_bytes,
                &mut ledger,
                &mut records,
                &mut last_snap,
                &mut culprit,
            ) {
                Ok(finals) => {
                    ledger.bytes_on_wire = wire_bytes;
                    self.tele.emit_with(|| Event::RunFinished {
                        rounds,
                        wall_seconds: t0.elapsed().as_secs_f64(),
                        messages: ledger.messages,
                        bytes: ledger.bytes,
                        wire_bytes,
                        drops: self.tele.dropped(),
                    });
                    return Ok(ExecTrace {
                        backend: "process",
                        topology: seq.name.clone(),
                        n,
                        max_degree: seq.max_degree(),
                        run: RunResult {
                            label: format!(
                                "{} × {} [process ×{k}]",
                                w.label(),
                                seq.name
                            ),
                            records: std::mem::take(&mut records),
                        },
                        ledger,
                        drops: 0,
                        trace: Trace::new(false),
                        wall_seconds: t0.elapsed().as_secs_f64(),
                        wire_matrix: (0..k)
                            .map(|s| pair_bytes[s * k..(s + 1) * k].to_vec())
                            .collect(),
                        finals,
                    });
                }
                Err(e) => {
                    let (resume_round, snap_ledger, snap_records) =
                        match (&last_snap, respawns_left) {
                            (Some(s), left) if left > 0 => (
                                s.round,
                                s.ledger.clone(),
                                s.records.clone(),
                            ),
                            _ => return Err(e),
                        };
                    self.tele.emit_with(|| Event::WorkerDied {
                        error: e.clone(),
                        respawns_left,
                    });
                    respawns_left -= 1;
                    epoch += 1;
                    // Heartbeat eviction: with a policy set and the dead
                    // shard attributed, its live nodes leave the roster
                    // and the Base-(k+1) sequence is rebuilt over the
                    // survivors, rotation-aligned at the snapshot round.
                    // The evicted shard still respawns — its nodes carry
                    // on as isolated ghosts (identity rows), exactly
                    // like a scheduled leave at the same boundary.
                    if let (Some(ev), Some(dead)) = (&self.evict, culprit)
                    {
                        let live: Vec<u32> = cur_roster
                            .clone()
                            .unwrap_or_else(|| (0..n as u32).collect());
                        let (gone, kept): (Vec<u32>, Vec<u32>) =
                            live.iter().copied().partition(|&i| {
                                splan.owner[i as usize] == dead
                            });
                        if !gone.is_empty() && kept.len() >= MIN_LIVE {
                            let ids: Vec<usize> = kept
                                .iter()
                                .map(|&i| i as usize)
                                .collect();
                            let new_seq = embedded_base(
                                n,
                                &ids,
                                ev.k,
                                resume_round,
                                &seq.name,
                            )?;
                            cross = new_seq
                                .phases
                                .iter()
                                .map(|p| {
                                    cross_shard_sources(
                                        p,
                                        &splan.owner,
                                        k,
                                    )
                                })
                                .collect();
                            for &d in &gone {
                                self.tele.emit_with(|| Event::NodeLeft {
                                    round: resume_round,
                                    node: d as usize,
                                    reason: "evicted",
                                });
                            }
                            self.tele.emit_with(|| {
                                Event::RosterResequenced {
                                    round: resume_round,
                                    epoch: epoch as usize,
                                    n_live: kept.len(),
                                }
                            });
                            cur_roster = Some(kept);
                            cur_seq = Some(new_seq);
                            if let Some(snap) = last_snap.as_mut() {
                                snap.roster = cur_roster.clone();
                            }
                        }
                    }
                    self.tele.emit_with(|| Event::WorkerRespawned {
                        start_round: resume_round,
                        attempt: self.max_respawns - respawns_left,
                    });
                    faults = (None, None);
                    ledger = snap_ledger;
                    records = snap_records;
                    culprit = None;
                }
            }
        }
    }

    fn run_ckpt<W: Workload>(
        &self,
        w: &mut W,
        seq: &GraphSequence,
        rounds: usize,
        ckpt: &CkptConfig,
    ) -> Result<ExecTrace, String> {
        let mut ex = self.clone();
        ex.ckpt = ckpt.clone();
        Executor::run(&ex, w, seq, rounds)
    }

    fn run_tel<W: Workload>(
        &self,
        w: &mut W,
        seq: &GraphSequence,
        rounds: usize,
        ckpt: &CkptConfig,
        tele: &Telemetry,
    ) -> Result<ExecTrace, String> {
        let mut ex = self.clone();
        ex.ckpt = ckpt.clone();
        ex.tele = tele.clone();
        Executor::run(&ex, w, seq, rounds)
    }
}

// ---------------------------------------------------------------------------
// Worker side
// ---------------------------------------------------------------------------

struct WorkerCtx {
    n: usize,
    rounds: usize,
    k: usize,
    shard: usize,
    owner: Vec<usize>,
    seq: GraphSequence,
    crash_round: Option<usize>,
    /// Mid-round fault injection: abort after sending this round's
    /// bundles, before receiving the neighbors'.
    crash_mid: Option<usize>,
    /// Checkpoint cadence (0 = off): at due boundaries the OBS frame
    /// carries each member node's [`Workload::node_ckpt`] blob.
    ckpt_every: usize,
    /// One-shot forced checkpoint round (elastic segment ends): ORed
    /// into the due rule exactly like the coordinator's.
    ckpt_force: Option<usize>,
    /// Worker generation, fenced on every BUNDLE/OBS frame: frames
    /// stamped with another generation are rejected as stale.
    epoch: u32,
    /// First round to execute; > 0 means a resume — skip the INIT
    /// observation (the coordinator restored that history) and restore
    /// member nodes from `resume` before the loop.
    start_round: usize,
    /// Per-member `(node, node_ckpt blob)` pairs when resuming.
    resume: Vec<(usize, Vec<u8>)>,
}

/// Entry point of the hidden `basegraph --worker <addr> <shard>` mode —
/// dispatched from `main` before normal CLI parsing.
pub fn worker_main(args: &[String]) -> Result<(), String> {
    if args.len() != 2 {
        return Err("usage: basegraph --worker <addr> <shard>".into());
    }
    let shard: usize = args[1]
        .parse()
        .map_err(|_| format!("bad shard id {:?}", args[1]))?;
    let token = std::env::var(TOKEN_ENV)
        .ok()
        .and_then(|t| u64::from_str_radix(&t, 16).ok())
        .ok_or_else(|| format!("missing or malformed ${TOKEN_ENV}"))?;
    let mut conn = connect(&args[0])?;
    conn.set_read_timeout(Some(Duration::from_secs(300)))
        .map_err(|e| format!("socket timeout: {e}"))?;
    let mut sink = 0u64;
    let mut hw = ByteWriter::new();
    hw.put_u32(shard as u32);
    hw.put_u64(token);
    send(&mut conn, FRAME_HELLO, &hw.finish(), &mut sink)?;
    match run_worker(&mut conn, shard) {
        Ok(()) => Ok(()),
        Err(e) => {
            // Best effort: hand the coordinator a real error message
            // before dying, so the failure is attributed, not inferred.
            let _ = write_frame(&mut conn, FRAME_ERROR, e.as_bytes());
            Err(format!("shard {shard}: {e}"))
        }
    }
}

fn run_worker(conn: &mut Conn, shard: usize) -> Result<(), String> {
    let mut sink = 0u64;
    let (kind, payload) = recv(conn, &mut sink)?;
    if kind != FRAME_CONFIG {
        return Err(format!("expected config frame, got kind {kind}"));
    }
    let mut r = ByteReader::new(&payload);
    let n = r.get_usize()?;
    let rounds = r.get_usize()?;
    let k = r.get_usize()?;
    let echo = r.get_usize()?;
    if echo != shard {
        return Err(format!("config addressed to shard {echo}, I am {shard}"));
    }
    let epoch = r.get_u32()?;
    let mut owner = Vec::with_capacity(n);
    for _ in 0..n {
        owner.push(r.get_u32()? as usize);
    }
    let seq_bytes = r.get_bytes()?;
    let spec_bytes = r.get_bytes()?;
    let crash = r.get_u64()?;
    let crash_mid = r.get_u64()?;
    let ckpt_every = r.get_u64()? as usize;
    let force_raw = r.get_u64()?;
    let ckpt_force = (force_raw != u64::MAX).then_some(force_raw as usize);
    let start_round = r.get_u64()? as usize;
    let resume_count = r.get_usize()?;
    let mut resume = Vec::with_capacity(resume_count);
    for _ in 0..resume_count {
        let node = r.get_u32()? as usize;
        let blob = r.get_bytes()?.to_vec();
        resume.push((node, blob));
    }
    // Live roster (0 entries = full). Validated here so a joiner
    // configured against the wrong capacity fails cleanly; membership
    // itself is enforced by the plan's identity rows.
    let roster_count = r.get_usize()?;
    let mut prev_id: Option<u32> = None;
    for _ in 0..roster_count {
        let id = r.get_u32()?;
        if id as usize >= n || prev_id.is_some_and(|p| p >= id) {
            return Err(format!(
                "config roster is not a strictly ascending subset of \
                 0..{n} (id {id})"
            ));
        }
        prev_id = Some(id);
    }
    r.expect_end()?;
    let mut sr = ByteReader::new(seq_bytes);
    let seq = wire::decode_seq(&mut sr)?;
    sr.expect_end()?;
    if seq.n != n {
        return Err(format!("config n {n} != topology n {}", seq.n));
    }
    let ctx = WorkerCtx {
        n,
        rounds,
        k,
        shard,
        owner,
        seq,
        crash_round: (crash != u64::MAX).then_some(crash as usize),
        crash_mid: (crash_mid != u64::MAX).then_some(crash_mid as usize),
        ckpt_every,
        ckpt_force,
        epoch,
        start_round,
        resume,
    };
    match decode_wire_spec(spec_bytes)? {
        DecodedSpec::Consensus { init, codec } => {
            let mut w = ConsensusWorkload::new(init).with_codec(codec);
            worker_loop(&mut w, conn, &ctx)
        }
        DecodedSpec::Training { spec, cfg, codec } => match spec {
            TrainSpec::Quadratic { d, seed } => {
                let (model, data) = quadratic_fixed_targets(ctx.n, d, seed);
                let mut w = TrainingWorkload::new(&model, &cfg, data, &[])
                    .with_codec(codec);
                worker_loop(&mut w, conn, &ctx)
            }
            TrainSpec::Classification { engine, alpha, seed } => {
                let engine = Engine::parse(&engine)?;
                let tw = classification_workload(&engine, seed)?;
                let data = partitioned_node_data(&tw, ctx.n, alpha, seed);
                let mut w = TrainingWorkload::new(
                    tw.provider.as_ref(),
                    &cfg,
                    data,
                    &[],
                )
                .with_codec(codec);
                worker_loop(&mut w, conn, &ctx)
            }
        },
    }
}

/// Ship one observation frame: per-member metric snapshots, then the
/// state section — a flag byte, plus (at checkpoint-due boundaries) each
/// member's full [`Workload::node_ckpt`] blob for the coordinator's
/// snapshot assembly.
#[allow(clippy::too_many_arguments)] // frame codec; sole caller is worker_loop
fn send_obs<W: Workload>(
    w: &W,
    conn: &mut Conn,
    members: &[usize],
    nodes: &[Option<W::Node>],
    marker: u32,
    epoch: u32,
    full: bool,
    states: bool,
    ow: &mut ByteWriter,
    sink: &mut u64,
) -> Result<(), String> {
    ow.clear();
    ow.put_u32(epoch);
    ow.put_u32(marker);
    ow.put_usize(members.len());
    for &i in members {
        ow.put_u32(i as u32);
        let node = nodes[i].as_ref().expect("member node");
        ow.put_bytes(&w.node_to_wire(node, full)?);
    }
    ow.put_u8(u8::from(states));
    if states {
        ow.put_usize(members.len());
        for &i in members {
            ow.put_u32(i as u32);
            let node = nodes[i].as_ref().expect("member node");
            ow.put_bytes(&w.node_ckpt(node)?);
        }
    }
    send(conn, FRAME_OBS, ow.as_slice(), sink)
}

/// The worker's round loop: local steps and combines for this shard's
/// nodes, payload bundles across the process boundary, observation
/// snapshots back to the coordinator. Same phases, same snapshot
/// discipline, same neighbor-list order as the in-process lock-step
/// engine — which is exactly why the results are bit-identical.
///
/// Buffers are round-persistent: payload snapshots are written in place
/// ([`Workload::make_payload_into`]), cross-shard bundles are encoded
/// straight into one reused frame writer
/// ([`Workload::payload_wire_into`]), received bundles decode into
/// per-node reused payload buffers ([`Workload::payload_from_wire_into`],
/// freshness-stamped per round so a protocol desync still surfaces), and
/// combines run through the slot-indexed availability table into one
/// recycled scratch.
fn worker_loop<W: Workload>(
    w: &mut W,
    conn: &mut Conn,
    ctx: &WorkerCtx,
) -> Result<(), String> {
    let n = ctx.n;
    let me = ctx.shard;
    let all = w.init_nodes(n)?;
    let mut nodes: Vec<Option<W::Node>> = all
        .into_iter()
        .enumerate()
        .map(|(i, nd)| (ctx.owner[i] == me).then_some(nd))
        .collect();
    let members: Vec<usize> =
        (0..n).filter(|&i| ctx.owner[i] == me).collect();
    // Resume: overwrite this shard's deterministically re-initialized
    // nodes with the snapshot's states before any round runs.
    if ctx.start_round > 0 {
        for (i, blob) in &ctx.resume {
            let node = nodes
                .get_mut(*i)
                .and_then(|s| s.as_mut())
                .ok_or_else(|| {
                    format!("resume state for foreign node {i}")
                })?;
            w.node_restore(node, blob)
                .map_err(|e| format!("restore node {i}: {e}"))?;
        }
    }
    // Which sources cross which shard boundary, per phase. Intra-shard
    // gossip reads the in-memory snapshot, so on block-local topologies
    // (contiguous shards on Base-(k+1)) most rounds encode almost
    // nothing.
    let cross: Vec<Vec<Vec<Vec<usize>>>> = ctx
        .seq
        .phases
        .iter()
        .map(|p| cross_shard_sources(p, &ctx.owner, ctx.k))
        .collect();
    // Per phase: which of our sources feed *more than one* remote shard —
    // those are worth encoding once into a cached buffer and splicing
    // per bundle; single-consumer sources encode straight into the
    // bundle frame (no intermediate copy at all).
    let multi_consumer: Vec<Vec<bool>> = cross
        .iter()
        .map(|xs| {
            let mut cnt = vec![0u8; n];
            for (t, bucket) in xs[me].iter().enumerate() {
                if t != me {
                    for &i in bucket {
                        cnt[i] = cnt[i].saturating_add(1);
                    }
                }
            }
            cnt.into_iter().map(|c| c > 1).collect()
        })
        .collect();
    let mut sink = 0u64;

    // Round-persistent buffers (see the function docs).
    let mut payloads: Vec<Option<W::Payload>> =
        (0..n).map(|_| None).collect();
    let mut remote: Vec<Option<W::Payload>> = (0..n).map(|_| None).collect();
    let mut remote_round: Vec<usize> = vec![usize::MAX; n];
    let mut avail: AvailTable<W::Payload> = AvailTable::new();
    let mut mix_scratch: Option<W::Payload> = None;
    let mut frame_w = ByteWriter::new();
    let mut frame_buf: Vec<u8> = Vec::new();
    // Encode-once cache for multi-consumer sources, round-stamped.
    let mut enc: Vec<ByteWriter> = (0..n).map(|_| ByteWriter::new()).collect();
    let mut enc_round: Vec<usize> = vec![usize::MAX; n];

    if ctx.start_round == 0 {
        send_obs(
            w, conn, &members, &nodes, INIT_ROUND, ctx.epoch, false, false,
            &mut frame_w, &mut sink,
        )?;
    }

    for r in ctx.start_round..ctx.rounds {
        if ctx.crash_round == Some(r) {
            // Fault injection: abort with no goodbye — the coordinator
            // must turn the dead socket into a clean error.
            std::process::exit(86);
        }
        let pidx = r % ctx.seq.len();
        let plan = ctx.seq.phase(r);
        let xs = &cross[pidx];

        for &i in &members {
            let node = nodes[i].as_mut().expect("member node");
            w.local_step(node, i, r)
                .map_err(|e| format!("node {i} round {r}: {e}"))?;
        }

        // Snapshot payloads in place; bundles encode straight out of
        // these buffers below.
        for &i in &members {
            let node = nodes[i].as_ref().expect("member node");
            let slot = &mut payloads[i];
            match slot {
                Some(buf) => w.make_payload_into(node, buf),
                None => *slot = Some(w.make_payload(node)),
            }
        }

        // One bundle per destination shard that needs anything of ours,
        // encoded into the reused frame writer.
        for t in 0..ctx.k {
            if t == me || xs[me][t].is_empty() {
                continue;
            }
            let srcs = &xs[me][t];
            frame_w.clear();
            frame_w.put_u32(ctx.epoch);
            frame_w.put_u32(r as u32);
            frame_w.put_u32(me as u32);
            frame_w.put_u32(t as u32);
            frame_w.put_usize(srcs.len());
            for &i in srcs {
                frame_w.put_u32(i as u32);
                let p = payloads[i].as_ref().expect("member payload");
                if multi_consumer[pidx][i] {
                    // Encode once per round, splice per bundle.
                    if enc_round[i] != r {
                        enc[i].clear();
                        w.payload_wire_into(p, &mut enc[i])?;
                        enc_round[i] = r;
                    }
                    frame_w.put_raw(enc[i].as_slice());
                } else {
                    w.payload_wire_into(p, &mut frame_w)?;
                }
            }
            send(conn, FRAME_BUNDLE, frame_w.as_slice(), &mut sink)
                .map_err(|e| format!("round {r}: send bundle → {t}: {e}"))?;
        }

        if ctx.crash_mid == Some(r) {
            // Mid-round fault injection: die *between* send and receive —
            // our bundles are in flight, our neighbors' never arrive. The
            // coordinator must recover from the last round-boundary
            // snapshot, not from this torn cut.
            std::process::exit(87);
        }

        // Receive the bundles other shards addressed to us, decoding
        // into the reused per-node buffers (stamped with this round).
        let expected = (0..ctx.k)
            .filter(|&s| s != me && !xs[s][me].is_empty())
            .count();
        for _ in 0..expected {
            let kind = recv_into(conn, &mut frame_buf, &mut sink)
                .map_err(|e| format!("round {r}: {e}"))?;
            if kind != FRAME_BUNDLE {
                return Err(format!(
                    "round {r}: expected a payload bundle, got frame kind \
                     {kind}"
                ));
            }
            let mut br = ByteReader::new(&frame_buf);
            let fe = br.get_u32()?;
            if fe != ctx.epoch {
                return Err(format!(
                    "round {r}: stale-epoch bundle (frame epoch {fe}, \
                     worker at {})",
                    ctx.epoch
                ));
            }
            let fr = br.get_u32()? as usize;
            let fsrc = br.get_u32()? as usize;
            let fdst = br.get_u32()? as usize;
            if fr != r || fdst != me {
                return Err(format!(
                    "round {r}: bundle out of sync (round {fr}, \
                     {fsrc} → {fdst})"
                ));
            }
            let count = br.get_usize()?;
            for _ in 0..count {
                let node = br.get_u32()? as usize;
                let bytes = br.get_bytes()?;
                if node >= n || ctx.owner[node] != fsrc {
                    return Err(format!(
                        "round {r}: bundle entry for foreign node {node}"
                    ));
                }
                let slot = &mut remote[node];
                match slot {
                    Some(buf) => w.payload_from_wire_into(bytes, buf)?,
                    None => *slot = Some(w.payload_from_wire(bytes)?),
                }
                remote_round[node] = r;
            }
            br.expect_end()?;
        }

        // Combine from snapshots through the availability table:
        // intra-shard from memory, cross-shard from the decoded bundles
        // (only if stamped fresh this round). Only this shard's member
        // rows are resolved — the others' would be O(total edges) of
        // wasted lookups per worker. Lock-step ideal network — every
        // neighbor payload must be present.
        avail.fill_rows(plan, &members, |_, _, j| {
            if ctx.owner[j] == me {
                payloads[j].as_ref()
            } else if remote_round[j] == r {
                remote[j].as_ref()
            } else {
                None
            }
        });
        for &i in &members {
            let row = avail.row(plan, i);
            if let Some(pos) = row.iter().position(|a| a.is_none()) {
                return Err(format!(
                    "round {r}: node {i} never received neighbor {}'s \
                     payload — protocol desync",
                    plan.neighbors(i)[pos].0
                ));
            }
            let node = nodes[i].as_mut().expect("member node");
            if mix_scratch.is_none() {
                mix_scratch = Some(w.alloc_payload(node));
            }
            let scr = mix_scratch.as_mut().expect("scratch");
            w.combine_into(node, i, r, plan, row, scr);
        }

        let eval = w.is_eval(r, ctx.rounds);
        let due = (ctx.ckpt_every > 0 && (r + 1) % ctx.ckpt_every == 0)
            || ctx.ckpt_force == Some(r + 1);
        send_obs(
            w, conn, &members, &nodes, r as u32, ctx.epoch, eval, due,
            &mut frame_w, &mut sink,
        )?;
    }

    let mut fw = ByteWriter::new();
    fw.put_usize(members.len());
    for &i in &members {
        fw.put_u32(i as u32);
        let node = nodes[i].as_ref().expect("member node");
        fw.put_bytes(&w.node_to_wire(node, true)?);
    }
    send(conn, FRAME_FINALS, &fw.finish(), &mut sink)?;

    // Hold the connection until the coordinator dismisses us (EOF from a
    // dead coordinator is also a dismissal).
    match read_frame(conn) {
        Ok((FRAME_SHUTDOWN, _, _)) | Err(_) => Ok(()),
        Ok((kind, _, _)) => {
            Err(format!("unexpected frame kind {kind} at shutdown"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_binds_uds_and_tcp() {
        let (l, addr) = Listener::bind(false).unwrap();
        #[cfg(unix)]
        assert!(addr.starts_with("uds:"), "{addr}");
        drop(l);
        let (_t, taddr) = Listener::bind(true).unwrap();
        assert!(taddr.starts_with("tcp:127.0.0.1:"), "{taddr}");
    }

    #[cfg(unix)]
    #[test]
    fn uds_socket_file_is_removed_on_drop() {
        let (l, addr) = Listener::bind(false).unwrap();
        let path = addr.strip_prefix("uds:").unwrap().to_string();
        assert!(std::path::Path::new(&path).exists());
        drop(l);
        assert!(!std::path::Path::new(&path).exists());
    }

    /// The read-timeout half of the crash satellite: a peer that never
    /// sends anything becomes a clean "timed out" error, not a hang.
    #[test]
    fn silent_peer_times_out_cleanly() {
        let (listener, addr) = Listener::bind(true).unwrap();
        let silent = connect(&addr).unwrap(); // never writes
        let conn = loop {
            match listener.accept() {
                Ok(c) => break c,
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(e) => panic!("accept: {e}"),
            }
        };
        conn.set_nonblocking(false).unwrap();
        conn.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
        let mut conn = conn;
        let t0 = Instant::now();
        let err = read_frame(&mut conn).unwrap_err();
        assert!(
            err.contains("timed out"),
            "expected a timeout error, got {err:?}"
        );
        assert!(t0.elapsed() < Duration::from_secs(5));
        drop(silent);
    }

    /// A peer that dies mid-frame is a truncation error, not a hang.
    #[test]
    fn dead_peer_mid_frame_is_truncation() {
        let (listener, addr) = Listener::bind(true).unwrap();
        let mut half = connect(&addr).unwrap();
        let conn = loop {
            match listener.accept() {
                Ok(c) => break c,
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(e) => panic!("accept: {e}"),
            }
        };
        conn.set_nonblocking(false).unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        // Send a frame claiming 100 payload bytes, deliver 3, hang up.
        let mut partial = Vec::new();
        partial.push(wire::MAGIC);
        partial.push(wire::VERSION);
        partial.push(FRAME_OBS);
        partial.extend_from_slice(&100u32.to_le_bytes());
        partial.extend_from_slice(b"abc");
        half.write_all(&partial).unwrap();
        half.flush().unwrap();
        drop(half);
        let mut conn = conn;
        let err = read_frame(&mut conn).unwrap_err();
        assert!(
            err.contains("truncated") || err.contains("closed"),
            "got {err:?}"
        );
    }

    #[test]
    fn worker_bin_resolution_reports_cleanly() {
        // In the unit-test binary (target/*/deps/basegraph-<hash>) the
        // ancestor search may or may not find a built CLI binary; either
        // way the call must not panic and an explicit override wins.
        let ex = ProcessExecutor::new(CostModel::default(), 2);
        let _ = ex.resolve_worker_bin();
        let ex = ex.with_worker_bin("/tmp/definitely-basegraph");
        assert_eq!(
            ex.resolve_worker_bin().unwrap(),
            PathBuf::from("/tmp/definitely-basegraph")
        );
    }

    #[test]
    fn bad_address_strings_error() {
        assert!(connect("carrier-pigeon:coop7").is_err());
        assert!(connect("tcp:127.0.0.1:1").is_err()); // nothing listens
    }
}
