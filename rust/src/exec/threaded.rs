//! The thread-parallel backend: nodes execute on real OS threads, so a
//! topology's communication structure shows up as **measured** wall-clock
//! seconds, not just as α–β model output or a virtual event clock.
//!
//! Each round, every node is claimed by a [`ThreadPool`] worker (one node
//! per worker when `threads >= n`; work-stealing over an atomic counter
//! otherwise). Payloads move through a double-buffered mailbox array —
//! the coordinator publishes snapshots into the back buffer (in place,
//! via [`Workload::make_payload_into`], so payload publishing never
//! touches the allocator in steady state; the pool's per-dispatch job
//! boxes are the remaining per-round allocation on parallel paths), the
//! buffers swap at the barrier, worker combines
//! read the front buffer through the shared slot-indexed availability
//! table and mix into per-node recycled scratch — and the pool's latch is
//! a real barrier: no node starts round r+1 until every node committed
//! round r. This is the BSP discipline of the simnet engine executed on
//! hardware; its process-boundary sibling is
//! [`ProcessExecutor`](super::ProcessExecutor), which runs the same
//! lock-step protocol across OS processes and real sockets.
//!
//! Determinism: identical to every other backend bit-for-bit (the
//! equivalence suite pins it) — combines read only snapshots, so thread
//! scheduling cannot reorder any floating-point operation.

use super::analytic::run_lockstep;
use super::{ExecTrace, Executor, Workload};
use crate::ckpt::CkptConfig;
use crate::comm::CostModel;
use crate::telemetry::Telemetry;
use crate::topology::GraphSequence;
use crate::util::threadpool::ThreadPool;

/// One node per [`ThreadPool`] worker, double-buffered mailboxes, a real
/// barrier per phase. `threads == 0` = available cores.
#[derive(Debug, Clone, Copy, Default)]
pub struct ThreadedExecutor {
    /// α–β model for the simulated-seconds column (the measured number is
    /// `ExecTrace::wall_seconds`).
    pub cost: CostModel,
    pub threads: usize,
}

impl ThreadedExecutor {
    pub fn new(cost: CostModel, threads: usize) -> Self {
        ThreadedExecutor { cost, threads }
    }

    fn pool_size(&self, n: usize) -> usize {
        let t = if self.threads == 0 {
            std::thread::available_parallelism()
                .map(|x| x.get())
                .unwrap_or(4)
        } else {
            self.threads
        };
        t.min(n.max(1)).max(1)
    }
}

impl Executor for ThreadedExecutor {
    fn backend(&self) -> &'static str {
        "threaded"
    }

    fn run<W: Workload>(
        &self,
        w: &mut W,
        seq: &GraphSequence,
        rounds: usize,
    ) -> Result<ExecTrace, String> {
        self.run_ckpt(w, seq, rounds, &CkptConfig::default())
    }

    fn run_ckpt<W: Workload>(
        &self,
        w: &mut W,
        seq: &GraphSequence,
        rounds: usize,
        ckpt: &CkptConfig,
    ) -> Result<ExecTrace, String> {
        self.run_tel(w, seq, rounds, ckpt, &Telemetry::off())
    }

    fn run_tel<W: Workload>(
        &self,
        w: &mut W,
        seq: &GraphSequence,
        rounds: usize,
        ckpt: &CkptConfig,
        tele: &Telemetry,
    ) -> Result<ExecTrace, String> {
        let pool = ThreadPool::new(self.pool_size(seq.n));
        // Always parallel — physically running the nodes is the point.
        run_lockstep(
            w,
            seq,
            rounds,
            &self.cost,
            Some(&pool),
            true,
            "threaded",
            ckpt,
            tele,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::consensus::gaussian_init;
    use crate::exec::{AnalyticExecutor, ConsensusWorkload};
    use crate::topology::base;
    use crate::util::rng::Rng;

    #[test]
    fn threaded_matches_analytic_and_measures_wall_clock() {
        let seq = base::base(16, 1).unwrap();
        let mut rng = Rng::new(9);
        let init = gaussian_init(16, 4, &mut rng);
        let a = AnalyticExecutor::serial()
            .run(&mut ConsensusWorkload::new(init.clone()), &seq, seq.len())
            .unwrap();
        let t = ThreadedExecutor::new(Default::default(), 3)
            .run(&mut ConsensusWorkload::new(init), &seq, seq.len())
            .unwrap();
        assert_eq!(t.backend, "threaded");
        assert_eq!(a.finals, t.finals, "threaded must be bit-identical");
        assert_eq!(a.errors(), t.errors());
        assert!(t.wall_seconds > 0.0);
        // Per-record wall clock is monotone non-decreasing.
        for w in t.run.records.windows(2) {
            assert!(w[1].wall_seconds >= w[0].wall_seconds);
        }
    }

    #[test]
    fn pool_sizing_respects_n_and_request() {
        let ex = ThreadedExecutor::new(Default::default(), 8);
        assert_eq!(ex.pool_size(4), 4);
        assert_eq!(ex.pool_size(100), 8);
        let auto = ThreadedExecutor::default();
        assert!(auto.pool_size(1000) >= 1);
        assert_eq!(auto.pool_size(1), 1);
    }
}
