//! The analytic backend: the ideal lock-step loop (every payload
//! delivered, every node in step), with α–β model seconds on the
//! simulated clock — the executor form of what `consensus::simulate` and
//! `train::train` used to hard-code.
//!
//! The lock-step engine here is shared with
//! [`ThreadedExecutor`](super::ThreadedExecutor): both run the same
//! publish-into-back-buffer / swap / combine-from-front-buffer round
//! (the "double-buffered mailbox"), they differ only in how much of each
//! phase runs on the thread pool. Results are bit-identical either way —
//! per-node work is independent and combines read only payload
//! snapshots.

use std::sync::Mutex;
use std::time::Instant;

use super::scratch::AvailTable;
use super::{ExecTrace, Executor, Workload};
use crate::ckpt::{CkptConfig, Snapshot};
use crate::comm::{CommLedger, CostModel};
use crate::metrics::RunResult;
use crate::simnet::event::Trace;
use crate::telemetry::{Event, Telemetry};
use crate::topology::GraphSequence;
use crate::util::threadpool::ThreadPool;

/// Ideal lock-step execution; `threads == 0` sizes the pool to the
/// machine (capped at 16, as the old trainer did). Workloads whose
/// [`parallel_hint`](Workload::parallel_hint) is false run fully serial.
#[derive(Debug, Clone, Copy, Default)]
pub struct AnalyticExecutor {
    pub cost: CostModel,
    pub threads: usize,
}

impl AnalyticExecutor {
    pub fn new(cost: CostModel, threads: usize) -> Self {
        AnalyticExecutor { cost, threads }
    }

    /// Fully serial executor — the cheapest dispatch for tiny per-node
    /// work (results are identical at any thread count regardless).
    pub fn serial() -> Self {
        AnalyticExecutor { cost: CostModel::default(), threads: 1 }
    }
}

impl Executor for AnalyticExecutor {
    fn backend(&self) -> &'static str {
        "analytic"
    }

    fn run<W: Workload>(
        &self,
        w: &mut W,
        seq: &GraphSequence,
        rounds: usize,
    ) -> Result<ExecTrace, String> {
        self.run_ckpt(w, seq, rounds, &CkptConfig::default())
    }

    fn run_ckpt<W: Workload>(
        &self,
        w: &mut W,
        seq: &GraphSequence,
        rounds: usize,
        ckpt: &CkptConfig,
    ) -> Result<ExecTrace, String> {
        self.run_tel(w, seq, rounds, ckpt, &Telemetry::off())
    }

    fn run_tel<W: Workload>(
        &self,
        w: &mut W,
        seq: &GraphSequence,
        rounds: usize,
        ckpt: &CkptConfig,
        tele: &Telemetry,
    ) -> Result<ExecTrace, String> {
        let (_, slot_bytes) = w.comm_shape();
        let pool = if w.parallel_hint() && self.threads != 1 {
            Some(if self.threads == 0 {
                ThreadPool::with_default_size(16)
            } else {
                ThreadPool::new(self.threads)
            })
        } else {
            None
        };
        // Parallel combine only pays off for large rows — the old
        // trainer's d·4 ≥ 16 KiB heuristic, kept verbatim.
        let parallel_combine = slot_bytes >= (1 << 14);
        run_lockstep(
            w,
            seq,
            rounds,
            &self.cost,
            pool.as_ref(),
            parallel_combine,
            "analytic",
            ckpt,
            tele,
        )
    }
}

/// The shared lock-step round engine (analytic + threaded backends).
///
/// Per round: local step on every node, publish payload snapshots into
/// the back mailbox buffer, swap buffers at the barrier, combine each
/// node from the front buffer (every payload present — the ideal
/// network), account one α–β round per message slot, observe.
///
/// Steady-state rounds are **allocation-free** in the engine (given a
/// workload whose scratch methods are implemented — both shipped ones
/// are; un-migrated workloads fall back to the allocating defaults):
/// the mailbox payloads are
/// allocated on the first two rounds and written in place thereafter
/// ([`Workload::make_payload_into`]), each node's combine scratch is
/// allocated at first use and recycled by [`Workload::combine_into`], and
/// the per-round availability table reuses one flat slot-indexed buffer
/// ([`AvailTable`]) instead of collecting a fresh `Vec<Option<&Payload>>`
/// per node. The allocation-regression test (`tests/alloc_regression.rs`)
/// pins this.
///
/// Checkpointing: `ckpt.resume` restores node states, ledger and record
/// history from a round-boundary [`Snapshot`] and continues at its round
/// (the initial record is part of the restored history, never re-taken);
/// `ckpt.policy` writes snapshots after due rounds commit. The lock-step
/// clock is implicit (the α–β ledger), so a snapshot's `clock`/`rng`
/// fields stay at their inert defaults here.
///
/// Telemetry: `run_started` after resume handling, `round_completed`
/// after each record commits (on the coordinator thread, outside the
/// pool dispatch), `checkpoint_written` after each snapshot rename,
/// `run_finished` with the final ledger totals. With [`Telemetry::off`]
/// every hook is a single branch — the steady-state round stays
/// allocation-free.
#[allow(clippy::too_many_arguments)] // internal engine; callers are the two backends
pub(super) fn run_lockstep<W: Workload>(
    w: &mut W,
    seq: &GraphSequence,
    rounds: usize,
    cost: &CostModel,
    pool: Option<&ThreadPool>,
    parallel_combine: bool,
    backend: &'static str,
    ckpt: &CkptConfig,
    tele: &Telemetry,
) -> Result<ExecTrace, String> {
    let n = seq.n;
    if n == 0 {
        return Err(format!("{backend} executor needs n >= 1"));
    }
    if rounds > 0 && seq.is_empty() {
        return Err(format!(
            "{backend} executor needs a non-empty phase sequence"
        ));
    }
    let t0 = Instant::now();
    let mut nodes = w.init_nodes(n)?;
    let w: &W = w;
    let (n_slots, slot_bytes) = w.comm_shape();
    let mut ledger = CommLedger::default();
    let mut records = Vec::with_capacity(rounds + 1);
    let mut start_round = 0usize;
    match ckpt.load_resume(n, &seq.name, rounds)? {
        Some(snap) => {
            for (node, blob) in nodes.iter_mut().zip(&snap.nodes) {
                w.node_restore(node, blob)?;
            }
            ledger = snap.ledger;
            records = snap.records;
            start_round = snap.round;
        }
        None => {
            if let Some(mut rec) = w.initial_record(&nodes) {
                rec.wall_seconds = t0.elapsed().as_secs_f64();
                records.push(rec);
            }
        }
    }
    tele.emit_with(|| Event::RunStarted {
        label: w.label(),
        backend,
        topology: seq.name.clone(),
        n,
        rounds,
        start_round,
    });
    // Double-buffered mailboxes: `front` is what every node reads this
    // round, `back` is where fresh payloads are published; they swap at
    // the barrier between the publish and combine phases, so a combine
    // can never observe a half-written mailbox.
    let mut front: Vec<Option<W::Payload>> = (0..n).map(|_| None).collect();
    let mut back: Vec<Option<W::Payload>> = (0..n).map(|_| None).collect();
    // Per-node combine scratch (allocated at first combine, then
    // recycled) and the slot-indexed availability table.
    let mut scratch: Vec<Option<W::Payload>> = (0..n).map(|_| None).collect();
    let mut avail: AvailTable<W::Payload> = AvailTable::new();
    let failure: Mutex<Option<(usize, String)>> = Mutex::new(None);

    for r in start_round..rounds {
        let plan = seq.phase(r);

        // 1. Local step on every node.
        match pool {
            Some(pool) => {
                pool.for_each_mut(&mut nodes, |i, node| {
                    if let Err(e) = w.local_step(node, i, r) {
                        let mut f = failure.lock().unwrap();
                        let replace = match f.as_ref() {
                            None => true,
                            Some((fi, _)) => i < *fi,
                        };
                        if replace {
                            *f = Some((i, e));
                        }
                    }
                });
                if let Some((_, e)) = failure.lock().unwrap().take() {
                    return Err(format!("round {r}: {e}"));
                }
            }
            None => {
                for (i, node) in nodes.iter_mut().enumerate() {
                    if let Err(e) = w.local_step(node, i, r) {
                        return Err(format!("round {r}: {e}"));
                    }
                }
            }
        }

        // 2. Publish payload snapshots — in place once the buffer exists
        //    — then swap mailboxes (barrier). Publishing runs on the
        //    coordinator thread: node state is `Send` but deliberately
        //    not required to be `Sync` (training nodes own non-Sync data
        //    streams), so workers never hold a shared view of the node
        //    array.
        for (slot, node) in back.iter_mut().zip(&nodes) {
            match slot {
                Some(buf) => w.make_payload_into(node, buf),
                None => *slot = Some(w.make_payload(node)),
            }
        }
        std::mem::swap(&mut front, &mut back);

        // 3. Rebuild the availability table: ideal network — every
        //    payload is present.
        avail.fill(plan, |_, _, j| front[j].as_ref());

        // 4. Combine: each node mixes its neighbors' published payloads
        //    from its slot-indexed table row, into its own scratch.
        let combine =
            |i: usize, node: &mut W::Node, slot: &mut Option<W::Payload>| {
                let row = avail.row(plan, i);
                if slot.is_none() {
                    *slot = Some(w.alloc_payload(node));
                }
                let scr = slot.as_mut().expect("scratch allocated above");
                w.combine_into(node, i, r, plan, row, scr);
            };
        let combine_t0 = Instant::now();
        match pool {
            Some(pool) if parallel_combine => {
                pool.for_each_mut2(&mut nodes, &mut scratch, combine);
            }
            _ => {
                let pairs = nodes.iter_mut().zip(scratch.iter_mut());
                for (i, (node, slot)) in pairs.enumerate() {
                    combine(i, node, slot);
                }
            }
        }
        let combine_ns = combine_t0.elapsed().as_nanos() as u64;

        // 5. Comm accounting: one α–β bulk-synchronous round per slot
        //    (the busiest node serializes its sends).
        for _ in 0..n_slots {
            ledger.record_round_bytes(plan, slot_bytes, cost);
        }

        // 6. Metrics.
        let eval = w.is_eval(r, rounds);
        let mut rec = w.observe(&nodes, r, eval)?;
        rec.cum_messages = ledger.messages;
        rec.cum_bytes = ledger.bytes;
        rec.sim_seconds = ledger.sim_seconds;
        rec.wall_seconds = t0.elapsed().as_secs_f64();
        rec.combine_ns = combine_ns;
        records.push(rec);
        let committed = records.last().expect("pushed above");
        tele.emit_with(|| Event::round(committed));

        // 7. Round-boundary snapshot, when due.
        if let Some(pol) = ckpt.policy.as_ref().filter(|p| p.due(r)) {
            let snap = Snapshot {
                topology: seq.name.clone(),
                n,
                round: r + 1,
                nodes: nodes
                    .iter()
                    .map(|s| w.node_ckpt(s))
                    .collect::<Result<_, String>>()?,
                ledger: ledger.clone(),
                records: records.clone(),
                clock: 0.0,
                rng: None,
                roster: ckpt.roster.clone(),
            };
            let path = pol.save(&snap)?;
            tele.emit_with(|| Event::CheckpointWritten {
                round: r + 1,
                path: path.display().to_string(),
            });
        }
    }

    tele.emit_with(|| Event::RunFinished {
        rounds,
        wall_seconds: t0.elapsed().as_secs_f64(),
        messages: ledger.messages,
        bytes: ledger.bytes,
        wire_bytes: ledger.bytes_on_wire,
        drops: tele.dropped(),
    });
    let finals = w.finals(&nodes);
    Ok(ExecTrace {
        backend,
        topology: seq.name.clone(),
        n,
        max_degree: seq.max_degree(),
        run: RunResult {
            label: format!("{} × {} [{}]", w.label(), seq.name, backend),
            records,
        },
        ledger,
        drops: 0,
        trace: Trace::new(false),
        wall_seconds: t0.elapsed().as_secs_f64(),
        wire_matrix: Vec::new(),
        finals,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::consensus::gaussian_init;
    use crate::exec::ConsensusWorkload;
    use crate::topology::base;
    use crate::util::rng::Rng;

    #[test]
    fn analytic_consensus_reaches_exact_in_one_sweep() {
        let seq = base::base(22, 3).unwrap();
        let mut rng = Rng::new(4);
        let init = gaussian_init(22, 2, &mut rng);
        let tr = AnalyticExecutor::serial()
            .run(&mut ConsensusWorkload::new(init), &seq, seq.len())
            .unwrap();
        assert_eq!(tr.backend, "analytic");
        assert_eq!(tr.run.records.len(), seq.len() + 1);
        assert!(tr.final_error() < 1e-20, "err={:e}", tr.final_error());
        let hit = tr.iters_to_reach(1e-18).expect("finite-time topology");
        assert!(hit <= seq.len(), "hit={hit} len={}", seq.len());
        // α–β clock moved, wall clock measured, no drops by definition.
        assert!(tr.sim_seconds() > 0.0);
        assert!(tr.wall_seconds > 0.0);
        assert_eq!(tr.drops, 0);
        let per_sweep: u64 =
            seq.phases.iter().map(|p| p.messages() as u64).sum();
        assert_eq!(tr.messages(), per_sweep);
    }

    #[test]
    fn empty_rounds_yield_initial_record_only() {
        let seq = base::base(8, 1).unwrap();
        let mut rng = Rng::new(0);
        let init = gaussian_init(8, 1, &mut rng);
        let tr = AnalyticExecutor::serial()
            .run(&mut ConsensusWorkload::new(init), &seq, 0)
            .unwrap();
        assert_eq!(tr.run.records.len(), 1);
        assert_eq!(tr.run.records[0].round, 0);
        assert_eq!(tr.messages(), 0);
    }
}
