//! The elastic-membership driver: churn as a sequence of static runs.
//!
//! [`run_elastic`] executes an [`ElasticSchedule`] — the deterministic
//! segment list produced by online Base-(k+1) resequencing
//! ([`crate::topology::resequence`]) — on *any* backend, by running each
//! segment as an ordinary fixed-topology run and carrying state across
//! the splice boundaries through the checkpoint machinery:
//!
//! ```text
//!   segment i                     boundary                segment i+1
//!   inner run over seg.seq   ──►  snapshot at seg.end ──► inner run,
//!   (force_at = seg.end)          · warm-start joiners    resumed from
//!                                 · stamp next roster     the rewritten
//!                                 · save (same path)      snapshot
//! ```
//!
//! The inner executor never learns about churn: each segment's
//! [`GraphSequence`] is embedded at full capacity (ghost nodes get
//! identity rows), rotation-aligned so `phase(r) = phases[r % len]`
//! keeps working with global round numbers, and shares one sequence
//! name across segments so snapshot topology validation holds through a
//! splice. Joiner warm starts call
//! [`Workload::node_warm_start`] with the donor blobs picked by
//! [`warm_start_donors`] — survivors' states are never touched, which
//! is what makes surviving-node columns bit-identical across backends
//! and across scheduled-vs-evicted churn at roster-change granularity.
//!
//! When the caller has no checkpoint policy of their own, boundary
//! snapshots go to a scratch directory under the system temp dir that
//! is removed when the run completes.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::ckpt::{CheckpointPolicy, CkptConfig, Snapshot};
use crate::exec::{ExecTrace, ExecutorKind, Workload};
use crate::telemetry::{Event, Telemetry};
use crate::topology::resequence::{warm_start_donors, ElasticSchedule};

/// Distinguishes concurrent scratch directories within one process
/// (integration tests run several elastic drivers in parallel).
static SCRATCH_SEQ: AtomicU64 = AtomicU64::new(0);

fn scratch_dir() -> PathBuf {
    let seq = SCRATCH_SEQ.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!(
        "basegraph-elastic-{}-{seq}",
        std::process::id()
    ))
}

fn roster_u32(roster: &[usize]) -> Vec<u32> {
    roster.iter().map(|&i| i as u32).collect()
}

/// Run an [`ElasticSchedule`] on `exec`, building a fresh workload per
/// segment via `make` (deterministic construction is the factory's
/// contract: every call must produce identically-initialized nodes —
/// restores overwrite them, but segment 0 runs from them directly).
///
/// `ckpt` is the *user's* checkpoint surface: its cadence and directory
/// are honored inside every segment, `resume` may point into any
/// segment (the driver fast-forwards past completed splices without
/// re-emitting their events), and segment-boundary snapshots are forced
/// on top via [`CheckpointPolicy::force_at`]. The returned trace is the
/// final segment's — its records, ledger and finals cover the whole run
/// because the record prefix rides in every snapshot.
///
/// Emits `node_left` (reason `"scheduled"`), `node_joined` and
/// `roster_resequenced` on `tele` at every boundary actually crossed.
pub fn run_elastic<W, F>(
    exec: &ExecutorKind,
    mut make: F,
    schedule: &ElasticSchedule,
    ckpt: &CkptConfig,
    tele: &Telemetry,
) -> Result<ExecTrace, String>
where
    W: Workload,
    F: FnMut() -> Result<W, String>,
{
    let nseg = schedule.segments.len();
    let (user_every, user_keep, dir, scratch) = match &ckpt.policy {
        Some(p) => (p.every_n_rounds, p.keep_last, p.dir.clone(), None),
        None => {
            let d = scratch_dir();
            // keep_last 0 = keep everything: boundary files must
            // survive until the driver consumes them.
            (0, 0, d.clone(), Some(d))
        }
    };

    // Where does the run start? Probe the user's resume snapshot (if
    // any) for its round, then map that to a segment. The probe skips
    // the roster check — the inner run re-validates against its own
    // segment roster.
    let probe = CkptConfig {
        policy: None,
        resume: ckpt.resume.clone(),
        roster: None,
    };
    let first = match probe.load_resume(
        schedule.capacity,
        &schedule.name,
        schedule.rounds,
    )? {
        Some(snap) => schedule.segment_index_for_resume(snap.round),
        None => 0,
    };

    let mut resume = ckpt.resume.clone();
    let mut result: Option<ExecTrace> = None;
    for (i, seg) in schedule.segments.iter().enumerate().skip(first) {
        let inner_policy = CheckpointPolicy {
            every_n_rounds: user_every,
            dir: dir.clone(),
            keep_last: user_keep,
            force_at: (i + 1 < nseg).then_some(seg.end),
        };
        let use_policy =
            ckpt.policy.is_some() || inner_policy.force_at.is_some();
        let inner = CkptConfig {
            policy: use_policy.then(|| inner_policy.clone()),
            resume: resume.take(),
            roster: Some(roster_u32(&seg.roster)),
        };
        let mut w = make()?;
        let trace = exec.run_tel(&mut w, &seg.seq, seg.end, &inner, tele)?;
        if i + 1 == nseg {
            result = Some(trace);
            break;
        }

        // Splice: rewrite the boundary snapshot for the next roster.
        let next = &schedule.segments[i + 1];
        let path = inner_policy.path_for(seg.end);
        let mut snap = Snapshot::load(&path).map_err(|e| {
            format!(
                "elastic splice at round {}: {e} (expected the forced \
                 segment-end snapshot at {})",
                seg.end,
                path.display()
            )
        })?;
        for &j in &next.joined {
            let donors = warm_start_donors(next, &seg.roster, j);
            let blobs: Vec<&[u8]> =
                donors.iter().map(|&d| snap.nodes[d].as_slice()).collect();
            snap.nodes[j] = w.node_warm_start(&blobs).map_err(|e| {
                format!("warm start of joining node {j}: {e}")
            })?;
        }
        snap.roster = Some(roster_u32(&next.roster));
        inner_policy.save(&snap)?;
        resume = Some(path);

        for &d in &next.left {
            tele.emit_with(|| Event::NodeLeft {
                round: seg.end,
                node: d,
                reason: "scheduled",
            });
        }
        for &j in &next.joined {
            tele.emit_with(|| Event::NodeJoined { round: seg.end, node: j });
        }
        tele.emit_with(|| Event::RosterResequenced {
            round: seg.end,
            epoch: i + 1,
            n_live: next.roster.len(),
        });
    }

    if let Some(d) = scratch {
        let _ = std::fs::remove_dir_all(&d);
    }
    result.ok_or_else(|| "elastic schedule has no segments".into())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::consensus::gaussian_init;
    use crate::exec::ConsensusWorkload;
    use crate::topology::resequence::RosterEvent;
    use crate::util::rng::Rng;

    fn consensus_factory(
        n: usize,
        seed: u64,
    ) -> impl FnMut() -> Result<ConsensusWorkload, String> {
        move || {
            let mut rng = Rng::new(seed);
            Ok(ConsensusWorkload::new(gaussian_init(n, 1, &mut rng)))
        }
    }

    #[test]
    fn fixed_schedule_matches_plain_run() {
        let n = 8;
        let sched = ElasticSchedule::fixed(n, 1, 12).unwrap();
        let exec = ExecutorKind::analytic();
        let elastic = run_elastic(
            &exec,
            consensus_factory(n, 5),
            &sched,
            &CkptConfig::default(),
            &Telemetry::off(),
        )
        .unwrap();
        let mut w = consensus_factory(n, 5)().unwrap();
        let plain =
            exec.run(&mut w, &sched.segments[0].seq, 12).unwrap();
        assert_eq!(elastic.finals, plain.finals);
        assert_eq!(
            elastic.run.records.len(),
            plain.run.records.len()
        );
    }

    #[test]
    fn churn_run_keeps_survivors_exact_and_warm_starts_joiners() {
        let n = 8;
        let events =
            [RosterEvent::leave(2, 6), RosterEvent::join(7, 6)];
        let sched = ElasticSchedule::build(n, 1, 18, &events).unwrap();
        assert!(sched.segments.len() >= 3, "{:?}", sched.segments.len());
        let exec = ExecutorKind::analytic();
        let trace = run_elastic(
            &exec,
            consensus_factory(n, 11),
            &sched,
            &CkptConfig::default(),
            &Telemetry::off(),
        )
        .unwrap();
        // Finite-time consensus holds per segment: by the end every
        // live node of the final roster agrees exactly.
        let last = sched.segments.last().unwrap();
        let lead = trace.finals[last.roster[0]][0];
        for &i in &last.roster {
            assert!(
                (trace.finals[i][0] - lead).abs() < 1e-9,
                "live node {i}: {} vs {lead}",
                trace.finals[i][0]
            );
        }
        // Determinism: a second identical run is bit-identical.
        let again = run_elastic(
            &exec,
            consensus_factory(n, 11),
            &sched,
            &CkptConfig::default(),
            &Telemetry::off(),
        )
        .unwrap();
        assert_eq!(trace.finals, again.finals);
    }
}
