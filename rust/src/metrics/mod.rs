//! Metric records for training runs and their CSV/JSONL serialization.

use crate::util::json::Json;

/// One evaluation point along a training run.
#[derive(Debug, Clone, Default)]
pub struct RoundRecord {
    pub round: usize,
    /// Mean local train loss over nodes this round.
    pub train_loss: f64,
    /// Parameter consensus error (1/n) Σ ||x_i − x̄||².
    pub consensus_error: f64,
    /// Test loss / accuracy of the node-averaged model (NaN when not
    /// evaluated this round).
    pub test_loss: f64,
    pub test_acc: f64,
    /// Cumulative communication.
    pub cum_messages: u64,
    pub cum_bytes: u64,
    /// Cumulative *measured* serialized socket bytes (process backend;
    /// 0 on in-process backends) — see `CommLedger::bytes_on_wire`.
    pub cum_wire_bytes: u64,
    pub sim_seconds: f64,
    /// Measured wall-clock seconds since the run started (0 for paths
    /// that predate the executor layer).
    pub wall_seconds: f64,
    /// Measured nanoseconds spent in the gossip-combine kernels this
    /// round (analytic executor; 0 where not instrumented).
    pub combine_ns: u64,
}

impl RoundRecord {
    pub fn csv_header() -> Vec<&'static str> {
        vec![
            "round",
            "train_loss",
            "consensus_error",
            "test_loss",
            "test_acc",
            "cum_messages",
            "cum_bytes",
            "cum_wire_bytes",
            "sim_seconds",
            "wall_seconds",
            "combine_ns",
        ]
    }

    pub fn csv_row(&self) -> Vec<String> {
        vec![
            self.round.to_string(),
            format!("{:.6}", self.train_loss),
            format!("{:.6e}", self.consensus_error),
            format!("{:.6}", self.test_loss),
            format!("{:.4}", self.test_acc),
            self.cum_messages.to_string(),
            self.cum_bytes.to_string(),
            self.cum_wire_bytes.to_string(),
            format!("{:.6}", self.sim_seconds),
            format!("{:.6}", self.wall_seconds),
            self.combine_ns.to_string(),
        ]
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("round", Json::num(self.round as f64)),
            ("train_loss", Json::num(self.train_loss)),
            ("consensus_error", Json::num(self.consensus_error)),
            ("test_loss", Json::num(self.test_loss)),
            ("test_acc", Json::num(self.test_acc)),
            ("cum_messages", Json::num(self.cum_messages as f64)),
            ("cum_bytes", Json::num(self.cum_bytes as f64)),
            ("cum_wire_bytes", Json::num(self.cum_wire_bytes as f64)),
            ("sim_seconds", Json::num(self.sim_seconds)),
            ("wall_seconds", Json::num(self.wall_seconds)),
            ("combine_ns", Json::num(self.combine_ns as f64)),
        ])
    }
}

/// When a run first crossed a quality target — the "time-to-accuracy"
/// record the simnet drivers exist to measure: the paper's
/// communication-efficiency claim, in simulated seconds and bytes rather
/// than round counts.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimeToTarget {
    /// The threshold that was crossed.
    pub target: f64,
    /// First round whose record met the target.
    pub round: usize,
    /// Simulated event-clock seconds at that round.
    pub sim_seconds: f64,
    /// Measured wall-clock seconds at that round (0 on pre-executor
    /// paths).
    pub wall_seconds: f64,
    /// Cumulative payload bytes moved by then.
    pub cum_bytes: u64,
    /// Cumulative directed messages by then.
    pub cum_messages: u64,
}

/// Full run result.
#[derive(Debug, Clone, Default)]
pub struct RunResult {
    pub label: String,
    pub records: Vec<RoundRecord>,
}

impl RunResult {
    /// First eval record with `test_acc >= target` (None if the run never
    /// got there).
    pub fn time_to_accuracy(&self, target: f64) -> Option<TimeToTarget> {
        self.records
            .iter()
            .find(|r| !r.test_acc.is_nan() && r.test_acc >= target)
            .map(|r| TimeToTarget {
                target,
                round: r.round,
                sim_seconds: r.sim_seconds,
                wall_seconds: r.wall_seconds,
                cum_bytes: r.cum_bytes,
                cum_messages: r.cum_messages,
            })
    }

    /// First record with `train_loss <= target` — the eval-free variant
    /// for workloads without test batches (consensus probes, quadratics).
    pub fn time_to_train_loss(&self, target: f64) -> Option<TimeToTarget> {
        self.records
            .iter()
            .find(|r| !r.train_loss.is_nan() && r.train_loss <= target)
            .map(|r| TimeToTarget {
                target,
                round: r.round,
                sim_seconds: r.sim_seconds,
                wall_seconds: r.wall_seconds,
                cum_bytes: r.cum_bytes,
                cum_messages: r.cum_messages,
            })
    }
    /// Final test accuracy (last evaluated record).
    pub fn final_acc(&self) -> f64 {
        self.records
            .iter()
            .rev()
            .find(|r| !r.test_acc.is_nan())
            .map(|r| r.test_acc)
            .unwrap_or(f64::NAN)
    }

    /// Best test accuracy over the run.
    pub fn best_acc(&self) -> f64 {
        self.records
            .iter()
            .filter(|r| !r.test_acc.is_nan())
            .map(|r| r.test_acc)
            .fold(f64::NAN, f64::max)
    }

    pub fn final_train_loss(&self) -> f64 {
        self.records.last().map(|r| r.train_loss).unwrap_or(f64::NAN)
    }

    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        let rows: Vec<Vec<String>> =
            self.records.iter().map(|r| r.csv_row()).collect();
        crate::util::write_csv(path, &RoundRecord::csv_header(), &rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn final_and_best_acc_skip_nan() {
        let mut rr = RunResult { label: "t".into(), records: vec![] };
        for (i, acc) in [(0, 0.1), (1, f64::NAN), (2, 0.5), (3, f64::NAN)] {
            rr.records.push(RoundRecord {
                round: i,
                test_acc: acc,
                ..Default::default()
            });
        }
        assert_eq!(rr.final_acc(), 0.5);
        assert_eq!(rr.best_acc(), 0.5);
    }

    #[test]
    fn time_to_accuracy_finds_first_crossing() {
        let mut rr = RunResult { label: "t".into(), records: vec![] };
        for (round, acc, loss, secs) in [
            (1, f64::NAN, 2.0, 0.1),
            (2, 0.4, 1.0, 0.2),
            (3, 0.7, 0.5, 0.3),
            (4, 0.9, 0.1, 0.4),
        ] {
            rr.records.push(RoundRecord {
                round,
                test_acc: acc,
                train_loss: loss,
                sim_seconds: secs,
                cum_bytes: round as u64 * 1000,
                cum_messages: round as u64 * 10,
                ..Default::default()
            });
        }
        let t = rr.time_to_accuracy(0.6).unwrap();
        assert_eq!(t.round, 3);
        assert_eq!(t.sim_seconds, 0.3);
        assert_eq!(t.cum_bytes, 3000);
        assert_eq!(t.cum_messages, 30);
        assert!(rr.time_to_accuracy(0.95).is_none());
        let l = rr.time_to_train_loss(0.6).unwrap();
        assert_eq!(l.round, 3);
        assert!(rr.time_to_train_loss(0.01).is_none());
    }

    #[test]
    fn csv_row_count_matches_header() {
        let r = RoundRecord::default();
        assert_eq!(r.csv_row().len(), RoundRecord::csv_header().len());
    }
}
