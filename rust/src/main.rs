//! `basegraph` — the command-line launcher for the BaseGraph reproduction.
//!
//! Subcommands:
//!   topology   inspect/validate a topology (length, degree, finite-time, β)
//!   list       print every buildable topology with degree + consensus horizon
//!   consensus  run the Sec. 6.1 consensus experiment and dump CSV
//!   train      run one decentralized training job (native or PJRT engine)
//!   simnet     race topologies on a simulated network (stragglers, drops)
//!   repro      regenerate a paper table/figure (see DESIGN.md index)
//!   bench      time the round engine (rounds/sec, bytes/round) and write
//!              BENCH_rounds.json — the perf trajectory's data points
//!   info       show the artifacts manifest and runtime status
//!
//! Run `basegraph <cmd> --help` for per-command flags.

use basegraph::ckpt::CkptConfig;
use basegraph::codec::Codec;
use basegraph::comm::CostModel;
use basegraph::consensus;
use basegraph::exec::{
    quadratic_fixed_targets, AllocatingWorkload, ConsensusWorkload,
    ExecTrace, ExecutorKind, TrainingWorkload,
};
use basegraph::optim::OptimizerKind;
use basegraph::repro;
use basegraph::repro::common::{
    classification_workload, print_table, run_training_exec_codec_tel,
    run_training_exec_elastic, Engine,
};
use basegraph::simnet::{
    ChurnPreset, ChurnSpec, CodecPolicy, ExecMode, LinkModel, Scenario,
};
use basegraph::telemetry::TelemetryConfig;
use basegraph::topology::{self, TopologyKind};
use basegraph::train::TrainConfig;
use basegraph::util::cli::Args;
use basegraph::util::json::{self, Json};
use basegraph::util::rng::Rng;

const USAGE: &str = "\
basegraph — Base-(k+1) Graph reproduction (NeurIPS 2023)

USAGE:
  basegraph topology  --kind <name> --n <n> [--seed S] [--validate]
  basegraph list      [--n N] [--seed S]
  basegraph consensus --n <n> [--iters I] [--topos a,b,c] [--out results]
  basegraph train     --topo <name> --n <n> [--alpha A] [--rounds R]
                      [--lr LR] [--optimizer dsgd|dsgdm|qg-dsgdm|d2|gt]
                      [--momentum M] [--seed S]
                      [--engine native-mlp|native-linear|pjrt:mlp:ref]
                      [--executor analytic|simnet|threaded|process]
                      [--threads N] [--shards N]
                      [--shard-balance contiguous|degree]
                      [--net-alpha SEC] [--net-beta SEC_PER_BYTE]
                      [--checkpoint-every N] [--checkpoint-dir DIR]
                      [--checkpoint-keep K] [--resume CKPT]
                      [--telemetry FILE|-] [--telemetry-http ADDR]
                      [--codec identity|bf16|f16|int8|topk[:permille]]
                      [--churn light|heavy|partition] [--churn-seed S]
                      [--churn-evict K] [--churn-kill SHARD@ROUND]
                      [--out results]
  basegraph simnet    [--scenario ideal|lan|wan|straggler|lossy|racks|
                                  hostile|churn-light|churn-heavy|partition]
                      [--mode bsp|async] [--workload consensus|train]
                      [--executor analytic|simnet|threaded|process]
                      [--threads N] [--shards N]
                      [--shard-balance contiguous|degree]
                      [--topos a,b,c] [--n N] [--seed S] [--out results]
                      [--alpha SEC] [--beta SEC_PER_BYTE] [--drop-rate P]
                      [--straggler-factor F]
                      [--codec C] [--codec-remote C] [--codec-rack-size N]
                      [--churn light|heavy|partition] [--churn-seed S]
                      [--churn-evict K] [--churn-kill SHARD@ROUND]
                      [--checkpoint-every N] [--checkpoint-dir DIR]
                      [--checkpoint-keep K] [--resume CKPT]
                      [--telemetry FILE|-] [--telemetry-http ADDR]
                      consensus: [--iters I] [--tol T]
                      train:     [--rounds R] [--lr LR] [--optimizer O]
                                 [--momentum M] [--engine E] [--dirichlet A]
                                 [--target-acc T]
  basegraph repro     --exp <id> [--fast] [--engine E] [--engine-deep E]
                      [--n N] [--ns a,b]
                      [--rounds R] [--seed S] [--out results]
                      [--executor analytic|simnet|threaded|process]
                      [--threads N] [--shards N]
                      [--shard-balance contiguous|degree]
                      [--codec C]
                      [--checkpoint-every N] [--checkpoint-dir DIR]
                      [--checkpoint-keep K] [--resume CKPT]
                      [--telemetry FILE|-] [--telemetry-http ADDR]
  basegraph bench     [--ns 64,256] [--ds 1000,100000] [--rounds R]
                      [--shards-list 2,4] [--fast] [--seed S]
                      [--codec identity,bf16,f16,int8,topk100]
                      [--telemetry FILE|-] [--telemetry-http ADDR]
                      [--out BENCH_rounds.json]
  basegraph info      [--artifacts DIR]

Topology names: ring, torus, exp, onepeer-exp, onepeer-hypercube, complete,
  base-<m>, simple-base-<m>, hh-<k>, u-equidyn, d-equidyn,
  u-equistatic-<deg>, d-equistatic-<deg>  (`basegraph list` enumerates them).
Experiments: table1 table2 equistatic fig5 fig6 fig7 fig8 fig9 fig21 fig22
  fig23 fig25 fig26 frontier simnet all
Executors: analytic (ideal lock-step loop, α–β model clock), simnet
  (event-driven network simulator), threaded (one node per worker thread —
  measured wall-clock), process (one worker OS process per node shard,
  gossip over real sockets — measured wall-clock and bytes-on-wire);
  --threads 0 = all cores; --shards N = worker processes (process backend).
Notes: in `simnet`, --alpha/--beta are the per-link α–β cost overrides and
  --dirichlet is the data-heterogeneity knob; in `train`, --alpha keeps its
  historical Dirichlet meaning and --net-alpha/--net-beta set the α–β cost.
Checkpointing: --checkpoint-every N snapshots every N rounds into
  --checkpoint-dir (rotating to --checkpoint-keep files); --resume takes a
  snapshot file, or a directory whose newest snapshot is used (an empty
  directory starts fresh — the crash-recovery form). Multi-run sweeps
  (simnet topology lists, repro figures) scope each run to its own
  subdirectory automatically; resumed runs replay bit-identically on all
  model columns (see docs/ARCHITECTURE.md, \"Checkpoint format &
  recovery\").
Codecs: --codec compresses every gossip payload at the source (identity =
  raw f32/f64; bf16/f16 = truncated floats; int8 = per-256-chunk shared-
  exponent bytes; topk[:permille] = sparse index+value pairs, default
  100‰). Training runs keep an error-feedback residual per neighbor slot
  so lossy codecs still converge; observations (losses, consensus error)
  stay full fidelity, and byte ledgers report exact compressed wire
  bytes. In `simnet`, --codec-remote C --codec-rack-size N additionally
  transcode payloads crossing rack boundaries (N=0 = every link) through
  a heavier codec, stateless per link. In `bench`, --codec takes a
  comma-separated roster for the codec cells.
Kernels: the hot elementwise loops (gossip combine, optimizer half-steps,
  codec quantize/pack) dispatch at runtime to AVX2 (x86-64) or NEON
  (aarch64) with a scalar fallback; vector and scalar paths are
  bit-identical by contract. BASEGRAPH_KERNELS=scalar forces the
  reference path (auto = detect, the default); `bench` emits per-cell
  scalar-vs-auto kernel columns.
Churn: --churn <preset> (or a churn-* simnet scenario) runs the workload
  under elastic membership — a seeded leave/join trace (--churn-seed,
  default = run seed) resolved into deterministic roster segments, the
  Base-(k+1) sequence resequenced online at every splice and joiners
  warm-started from surviving neighbors. Requires a base-<m> topology
  and bulk-synchronous execution; nodes outside the roster compute solo
  (ghost cohort) and rejoin by warm start. On --executor process,
  --churn-evict K additionally evicts a dead worker's nodes on
  heartbeat timeout and resequences the survivors at degree K, and
  --churn-kill SHARD@ROUND aborts one worker at a round boundary (fault
  injection for recovery drills). Events stream as node_left /
  node_joined / roster_resequenced telemetry.
Telemetry: --telemetry FILE streams one NDJSON event per line (`-` =
  stdout; versioned schema, byte-identical across same-seed runs modulo
  wall-clock fields); --telemetry-http ADDR serves GET /status (JSON
  snapshot: round, rolling rounds/sec, worker liveness, last checkpoint)
  and GET /events?since=SEQ from a dedicated thread — a slow scraper
  drops events past a bounded buffer, it never stalls the round loop.
  Multi-run sweeps scope each run to its own stream file, exactly like
  checkpoint subdirectories (see docs/ARCHITECTURE.md, \"Telemetry &
  live observability\").
Docs: docs/ARCHITECTURE.md is the full tour (layers, backends, wire
  protocol, determinism rules) with a complete CLI flag reference.
Help: `basegraph --help` (or any subcommand with --help) prints this.";

fn main() {
    // Resolve BASEGRAPH_KERNELS before anything touches a kernel, so a
    // bogus value is a clean CLI error instead of a mid-run panic.
    if let Err(e) = basegraph::kernels::init_from_env() {
        eprintln!("error: {e}");
        std::process::exit(2);
    }
    let raw: Vec<String> = std::env::args().skip(1).collect();
    // Hidden re-exec mode of the process-parallel executor: the
    // coordinator spawns `basegraph --worker <addr> <shard>` per node
    // shard. Deliberately not in USAGE — it is an implementation detail
    // of `--executor process`, not a user-facing command.
    if raw.first().map(|s| s.as_str()) == Some("--worker") {
        if let Err(e) = basegraph::exec::process::worker_main(&raw[1..]) {
            eprintln!("worker error: {e}");
            std::process::exit(1);
        }
        return;
    }
    if raw.is_empty() || raw[0] == "--help" || raw[0] == "help" {
        println!("{USAGE}");
        return;
    }
    let cmd = raw[0].clone();
    let args = match Args::parse(&raw[1..], &["validate", "fast", "help"]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if args.flag("help") {
        println!("{USAGE}");
        return;
    }
    let result = match cmd.as_str() {
        "topology" => cmd_topology(&args),
        "list" => cmd_list(&args),
        "consensus" => cmd_consensus(&args),
        "train" => cmd_train(&args),
        "simnet" => cmd_simnet(&args),
        "repro" => repro::run(&args),
        "bench" => cmd_bench(&args),
        "info" => cmd_info(&args),
        other => Err(format!("unknown command {other:?}\n\n{USAGE}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn cmd_topology(args: &Args) -> Result<(), String> {
    let kind = TopologyKind::parse(&args.str_or("kind", "base-2"))?;
    let n = args.usize_or("n", 25)?;
    let seed = args.u64_or("seed", 0)?;
    let seq = kind.build(n, seed)?;
    let mut rng = Rng::new(seed);
    // Spectral β and the finite-time product need the dense view (O(n²)
    // memory, O(n³) work) — skip them at scale, where the sparse plan is
    // the whole point.
    let (beta, finite) = if n <= 1024 {
        // One product serves both checks (it is the dominant cost here).
        let prod = seq.product();
        let beta = prod.consensus_rate(300, &mut rng);
        let finite = prod
            .max_abs_diff(&basegraph::MixingMatrix::average(seq.n))
            <= 1e-9;
        (format!("{beta:.6}"), finite.to_string())
    } else {
        ("skipped (n>1024)".into(), "skipped (n>1024)".into())
    };
    let rows = vec![vec![
        kind.label(),
        n.to_string(),
        seq.len().to_string(),
        seq.max_degree().to_string(),
        finite,
        beta,
    ]];
    print_table(
        "topology",
        &["name", "n", "phases", "max deg", "finite-time", "sweep β"],
        &rows,
    );
    if args.flag("validate") {
        for (i, p) in seq.phases.iter().enumerate() {
            // Sparse O(edges) check — no dense matrix even at large n.
            if !p.is_doubly_stochastic(1e-9) {
                return Err(format!("phase {i} is not doubly stochastic"));
            }
        }
        println!(
            "validation OK: all phases doubly stochastic; degree ≤ {}",
            seq.max_degree()
        );
    }
    Ok(())
}

/// `basegraph list`: every buildable topology at `--n`, with its CLI name,
/// phase count, max degree, per-sweep message count, finite-time
/// consensus horizon (iterations of gossip to numerically exact consensus,
/// measured — `>cap` when the topology only converges geometrically) and
/// measured spectral consensus rate β of the full sweep (dense-view
/// analysis, skipped at large n) — or the reason it cannot be built at
/// that n. Enough to pick simnet scenario rosters without reading source.
fn cmd_list(args: &Args) -> Result<(), String> {
    let n = args.usize_or("n", 25)?;
    let seed = args.u64_or("seed", 0)?;
    let mut rows = Vec::new();
    for kind in topology::catalog() {
        let row = match kind.build(n, seed) {
            Ok(seq) => {
                let msgs: usize =
                    seq.phases.iter().map(|p| p.messages()).sum();
                let horizon = if n <= 2048 {
                    let cap = (4 * seq.len()).clamp(16, 200);
                    consensus::paper_consensus_experiment(&seq, cap, seed)
                        .iters_to_reach(1e-18)
                        .map(|i| i.to_string())
                        .unwrap_or_else(|| format!(">{cap}"))
                } else {
                    "skipped (n>2048)".into()
                };
                // Measured consensus rate β of the sweep operator: the
                // EquiStatic-comparison column (dense view — O(n²)
                // memory — so capped).
                let beta = if n <= 512 {
                    let mut rng = Rng::new(seed);
                    format!(
                        "{:.4}",
                        seq.product().consensus_rate(300, &mut rng)
                    )
                } else {
                    "skipped (n>512)".into()
                };
                vec![
                    kind.to_cli_name(),
                    kind.label(),
                    seq.len().to_string(),
                    seq.max_degree().to_string(),
                    horizon,
                    beta,
                    msgs.to_string(),
                ]
            }
            Err(e) => vec![
                kind.to_cli_name(),
                kind.label(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                format!("unavailable: {e}"),
            ],
        };
        rows.push(row);
    }
    print_table(
        &format!("topologies at n={n}"),
        &[
            "cli name",
            "label",
            "phases",
            "max deg",
            "consensus horizon",
            "sweep β",
            "msgs/sweep",
        ],
        &rows,
    );
    Ok(())
}

fn cmd_consensus(args: &Args) -> Result<(), String> {
    let n = args.usize_or("n", 25)?;
    let iters = args.usize_or("iters", 60)?;
    let seed = args.u64_or("seed", 42)?;
    let out_dir = args.str_or("out", "results");
    let topos = args.str_list_or(
        "topos",
        &["ring", "exp", "onepeer-exp", "base-2", "base-4"],
    );
    std::fs::create_dir_all(&out_dir).map_err(|e| e.to_string())?;
    let mut rows = Vec::new();
    let mut header = vec!["iter".to_string()];
    let mut series = Vec::new();
    for t in &topos {
        let kind = TopologyKind::parse(t)?;
        let seq = kind.build(n, seed)?;
        let trace = consensus::paper_consensus_experiment(&seq, iters, seed);
        header.push(kind.label());
        rows.push(vec![
            kind.label(),
            seq.max_degree().to_string(),
            trace
                .iters_to_reach(1e-20)
                .map(|i| i.to_string())
                .unwrap_or_else(|| "never".into()),
            format!("{:.3e}", trace.errors[iters]),
        ]);
        series.push(trace.errors);
    }
    let csv_rows: Vec<Vec<String>> = (0..=iters)
        .map(|it| {
            let mut row = vec![it.to_string()];
            for s in &series {
                row.push(format!("{:.6e}", s[it]));
            }
            row
        })
        .collect();
    let path = format!("{out_dir}/consensus_n{n}.csv");
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    basegraph::util::write_csv(&path, &header_refs, &csv_rows)
        .map_err(|e| e.to_string())?;
    print_table(
        &format!("consensus at n={n} (CSV: {path})"),
        &["topology", "max deg", "iters to exact", "err@end"],
        &rows,
    );
    Ok(())
}

/// Parse the `--churn-*` surface shared by `train` and `simnet`: the
/// preset override (`--churn`, re-seeded by `--churn-seed`), the
/// heartbeat-eviction degree (`--churn-evict`) and the fault-injection
/// kill point (`--churn-kill <shard>@<round>`).
#[allow(clippy::type_complexity)]
fn churn_args(
    args: &Args,
    default_seed: u64,
) -> Result<
    (Option<ChurnSpec>, Option<usize>, Option<(usize, usize)>),
    String,
> {
    let spec = match args.get("churn") {
        None => None,
        Some(p) => Some(ChurnSpec::new(
            ChurnPreset::parse(p)?,
            args.u64_or("churn-seed", default_seed)?,
        )),
    };
    let evict = match args.get("churn-evict") {
        None => None,
        Some(_) => {
            let k = args.usize_or("churn-evict", 0)?;
            if k == 0 {
                return Err("--churn-evict must be >= 1".into());
            }
            Some(k)
        }
    };
    let kill = match args.get("churn-kill") {
        None => None,
        Some(v) => {
            let (s, r) = v.split_once('@').ok_or_else(|| {
                format!("--churn-kill expects <shard>@<round>, got {v:?}")
            })?;
            let shard = s.trim().parse::<usize>().map_err(|_| {
                format!("--churn-kill shard: expected integer, got {s:?}")
            })?;
            let round = r.trim().parse::<usize>().map_err(|_| {
                format!("--churn-kill round: expected integer, got {r:?}")
            })?;
            Some((shard, round))
        }
    };
    Ok((spec, evict, kill))
}

/// Resolve a churn spec into the elastic schedule for one topology.
/// Online resequencing rebuilds the Base-(k+1) construction per roster,
/// so only `base-<m>` topologies qualify.
fn churn_schedule(
    kind: &TopologyKind,
    n: usize,
    rounds: usize,
    spec: ChurnSpec,
) -> Result<basegraph::topology::resequence::ElasticSchedule, String> {
    let k = match kind {
        TopologyKind::Base { m } if *m >= 2 => *m - 1,
        other => {
            return Err(format!(
                "churn runs resequence online via the Base-(k+1) \
                 construction, which needs a base-<m> topology (m >= 2); \
                 got {}",
                other.label()
            ))
        }
    };
    let trace = spec.resolve(n, rounds);
    basegraph::topology::resequence::ElasticSchedule::build(
        n,
        k,
        rounds,
        &trace.events,
    )
}

fn cmd_train(args: &Args) -> Result<(), String> {
    let kind = TopologyKind::parse(&args.str_or("topo", "base-2"))?;
    let n = args.usize_or("n", 25)?;
    let alpha = args.f64_or("alpha", 0.1)?;
    let rounds = args.usize_or("rounds", 200)?;
    let lr = args.f64_or("lr", 0.5)?;
    let seed = args.u64_or("seed", 42)?;
    let momentum = args.f64_or("momentum", 0.9)? as f32;
    let optimizer =
        OptimizerKind::parse(&args.str_or("optimizer", "dsgdm"), momentum)?;
    let engine = Engine::parse(&args.str_or("engine", "native-mlp"))?;
    let out_dir = args.str_or("out", "results");
    // α–β communication cost model, previously hard-coded defaults.
    let default_cost = CostModel::default();
    let cost = CostModel {
        alpha: args.f64_or("net-alpha", default_cost.alpha)?,
        beta: args.f64_or("net-beta", default_cost.beta)?,
    };
    // Execution backend: ideal analytic loop (default), event-driven
    // simnet, real threads, or one worker process per node shard.
    let (churn, evict, kill) = churn_args(args, seed)?;
    let exec = ExecutorKind::from_args(args, "analytic")?
        .with_cost(cost)
        .with_evict(evict)
        .with_kill(kill);
    let codec = Codec::parse(&args.str_or("codec", "identity"))?;
    let ckpt = CkptConfig::from_args(args)?;
    let tsession = TelemetryConfig::from_args(args).session()?;
    std::fs::create_dir_all(&out_dir).map_err(|e| e.to_string())?;

    let workload = classification_workload(&engine, seed)?;
    println!(
        "training {} on {} (n={n}, α={alpha}, {} rounds, lr={lr}, {}, \
         executor {}, codec {})",
        workload.provider.name(),
        kind.label(),
        rounds,
        optimizer.label(),
        exec.label(),
        codec.label()
    );
    let res = match churn {
        Some(spec) => {
            let schedule = churn_schedule(&kind, n, rounds, spec)?;
            println!(
                "churn preset {} (seed {}): {} roster segment(s) over \
                 {rounds} rounds",
                spec.preset.label(),
                spec.seed,
                schedule.segments.len()
            );
            run_training_exec_elastic(
                &workload, &schedule, alpha, optimizer, lr, seed, &exec,
                &ckpt, &tsession.run("")?, codec,
            )?
        }
        None => run_training_exec_codec_tel(
            &workload, kind, n, alpha, optimizer, rounds, lr, seed, &exec,
            &ckpt, &tsession.run("")?, codec,
        )?,
    };
    let path = format!(
        "{out_dir}/train_{}_n{n}.csv",
        args.str_or("topo", "base-2")
    );
    res.run.write_csv(&path).map_err(|e| e.to_string())?;
    let evals: Vec<Vec<String>> = res
        .run
        .records
        .iter()
        .filter(|r| !r.test_acc.is_nan())
        .map(|r| {
            vec![
                r.round.to_string(),
                format!("{:.4}", r.train_loss),
                format!("{:.2}", 100.0 * r.test_acc),
                format!("{:.2e}", r.consensus_error),
                format!("{:.1}", r.cum_bytes as f64 / 1e6),
                format!("{:.3}", r.wall_seconds),
            ]
        })
        .collect();
    print_table(
        &format!("training curve (CSV: {path})"),
        &[
            "round",
            "train loss",
            "test acc %",
            "consensus",
            "comm MB",
            "wall s",
        ],
        &evals,
    );
    println!(
        "executor {}: {:.3}s wall, {:.4}s simulated, {} messages",
        res.backend,
        res.wall_seconds,
        res.ledger.sim_seconds,
        res.ledger.messages
    );
    print_wire_matrix(&res);
    Ok(())
}

/// Process-backend wire summary: measured bytes routed through the
/// coordinator per (src, dst) shard pair (both hops of every bundle).
/// Empty on the in-process backends, so this prints nothing there.
fn print_wire_matrix(res: &ExecTrace) {
    if res.wire_matrix.is_empty() {
        return;
    }
    let k = res.wire_matrix.len();
    let header: Vec<String> = std::iter::once("src \\ dst (MB)".to_string())
        .chain((0..k).map(|d| format!("→{d}")))
        .collect();
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let rows: Vec<Vec<String>> = res
        .wire_matrix
        .iter()
        .enumerate()
        .map(|(s, row)| {
            std::iter::once(format!("shard {s}"))
                .chain(row.iter().map(|&b| format!("{:.2}", b as f64 / 1e6)))
                .collect()
        })
        .collect();
    print_table(
        "coordinator wire matrix (measured MB per shard pair)",
        &header_refs,
        &rows,
    );
}

/// `basegraph simnet`: race topologies on the simulated network — scenario
/// preset + knob overrides, bulk-synchronous or asynchronous execution,
/// consensus or training workload.
fn cmd_simnet(args: &Args) -> Result<(), String> {
    let n = args.usize_or("n", 25)?;
    let seed = args.u64_or("seed", 42)?;
    let scenario = Scenario::parse(&args.str_or("scenario", "lan"))?;
    let mode = ExecMode::parse(&args.str_or("mode", "bsp"))?;
    let out_dir = args.str_or("out", "results");
    std::fs::create_dir_all(&out_dir).map_err(|e| e.to_string())?;

    let mut sim = scenario.config(seed);
    sim.mode = mode;
    // Knob overrides layered over the scenario preset.
    let opt_f64 = |key: &str| -> Result<Option<f64>, String> {
        match args.get(key) {
            None => Ok(None),
            Some(v) => v.parse::<f64>().map(Some).map_err(|_| {
                format!("--{key}: expected number, got {v:?}")
            }),
        }
    };
    let alpha = opt_f64("alpha")?;
    let beta = opt_f64("beta")?;
    for (name, v) in [("alpha", alpha), ("beta", beta)] {
        if let Some(v) = v {
            if v < 0.0 {
                return Err(format!("--{name} must be >= 0, got {v}"));
            }
        }
    }
    sim.links.override_cost(alpha, beta);
    if let Some(p) = opt_f64("drop-rate")? {
        if !(0.0..=1.0).contains(&p) {
            return Err(format!("--drop-rate must be in [0,1], got {p}"));
        }
        sim.drop_rate = p;
    }
    if let Some(f) = opt_f64("straggler-factor")? {
        if f <= 0.0 {
            return Err(format!(
                "--straggler-factor must be > 0, got {f}"
            ));
        }
        sim.compute.straggler_factor = f;
        // Make the flag effective even from presets without stragglers.
        if f != 1.0 && sim.compute.straggler_frac == 0.0 {
            sim.compute.straggler_frac = 0.125;
        }
        if f != 1.0 && sim.compute.mean_seconds == 0.0 {
            sim.compute.mean_seconds = 5e-3;
        }
    }
    // Gossip wire codec: --codec compresses every payload at the source
    // (all backends); --codec-remote + --codec-rack-size additionally
    // transcode payloads that cross rack boundaries inside the
    // event-driven simulator (rack size 0 = every link is remote).
    let codec = Codec::parse(&args.str_or("codec", "identity"))?;
    if let Some(c) = args.get("codec-remote") {
        let remote = Codec::parse(c)?;
        let rack = args.usize_or("codec-rack-size", 0)?;
        sim.codec_policy = CodecPolicy::remote_links(remote, rack);
    } else if args.get("codec-rack-size").is_some() {
        return Err("--codec-rack-size requires --codec-remote".into());
    }
    // Elastic membership: churn-* scenarios carry a seeded trace spec;
    // --churn layers one over any scenario, and --churn-seed re-seeds
    // either form. The elastic driver resolves the spec against each
    // run's (n, rounds) — see docs/ARCHITECTURE.md, "Elastic membership
    // & resequencing".
    let (churn_flag, evict, kill) = churn_args(args, seed)?;
    if let Some(spec) = churn_flag {
        sim.churn = Some(spec);
    } else if let Some(spec) = sim.churn.as_mut() {
        spec.seed = args.u64_or("churn-seed", spec.seed)?;
    }
    let churn = sim.churn;
    if churn.is_some() && mode == ExecMode::Async {
        return Err(
            "churn requires --mode bsp: roster splices happen at \
             bulk-synchronous round boundaries"
                .into(),
        );
    }
    // Churn runs can only race topologies that resequence (base-<m>),
    // so the default roster narrows accordingly.
    let default_topos: &[&str] = if churn.is_some() {
        &["base-2", "base-4"]
    } else {
        &["ring", "exp", "onepeer-exp", "base-2", "base-4"]
    };
    let topos = args.str_list_or("topos", default_topos);
    // Backend selection: the event-driven simulator is the default here;
    // `--executor analytic|threaded|process` races the same workload on
    // the ideal lock-step loop, on real threads, or on real worker
    // processes. The lock-step backends inherit the scenario's α–β link
    // cost (worst link class, with any --alpha/--beta overrides already
    // applied) so the sim-seconds column stays comparable to an
    // event-driven run of the same scenario; they are inherently
    // bulk-synchronous, so async mode is rejected.
    let exec = ExecutorKind::from_args(args, "simnet")?;
    let lockstep_cost = match &sim.links {
        LinkModel::Uniform(c) => *c,
        LinkModel::Racks { remote, .. } => *remote,
    };
    if !matches!(exec, ExecutorKind::Simnet(_)) {
        if mode == ExecMode::Async {
            return Err(format!(
                "--mode async requires --executor simnet (the {} backend \
                 is bulk-synchronous)",
                exec.label()
            ));
        }
        // Drops and stragglers only exist in the event-driven simulator;
        // running a scenario that implies them on a lock-step backend
        // would silently produce ideal-network numbers under a lossy
        // label.
        if sim.drop_rate > 0.0
            || (sim.compute.straggler_factor != 1.0
                && sim.compute.straggler_frac > 0.0)
        {
            return Err(format!(
                "scenario {} implies drops/stragglers, which the {} \
                 backend cannot simulate; use --executor simnet (or an \
                 ideal/lan/wan/racks scenario)",
                scenario.label(),
                exec.label()
            ));
        }
        // Per-link transcoding happens inside the event engine's
        // delivery path; the lock-step backends have no per-link hook.
        if sim.codec_policy.remote.is_some() {
            return Err(format!(
                "--codec-remote requires --executor simnet (the {} \
                 backend has no per-link delivery path)",
                exec.label()
            ));
        }
    }
    let exec = exec
        .with_cost(lockstep_cost)
        .with_sim(sim.clone())
        .with_evict(evict)
        .with_kill(kill);
    // Checkpoint/resume: racing several topologies in one invocation
    // scopes each run to its own subdirectory (see CkptConfig::scoped),
    // so a sweep's snapshots never rotate each other away.
    let ckpt = CkptConfig::from_args(args)?;
    // Telemetry mirrors the checkpoint scoping: one session (seq counter
    // + HTTP listener) per invocation, one scoped NDJSON stream per
    // raced topology.
    let tsession = TelemetryConfig::from_args(args).session()?;

    match args.str_or("workload", "consensus").as_str() {
        "consensus" => {
            let iters = args.usize_or("iters", 80)?;
            let tol = args.f64_or("tol", 1e-9)?;
            let mut rows = Vec::new();
            let mut csv = Vec::new();
            for t in &topos {
                let kind = TopologyKind::parse(t)?;
                let seq = kind.build(n, seed)?;
                let tr = match churn {
                    Some(spec) => {
                        let schedule = churn_schedule(&kind, n, iters, spec)?;
                        consensus::consensus_experiment_elastic(
                            &schedule,
                            seed,
                            &exec,
                            &ckpt.scoped(t),
                            &tsession.run(t)?,
                            codec,
                        )?
                    }
                    None => consensus::consensus_experiment_codec_tel(
                        &seq,
                        iters,
                        seed,
                        &exec,
                        &ckpt.scoped(t),
                        &tsession.run(t)?,
                        codec,
                    )?,
                };
                rows.push(vec![
                    kind.label(),
                    seq.max_degree().to_string(),
                    tr.time_to_reach(tol)
                        .map(|s| format!("{s:.4}"))
                        .unwrap_or_else(|| "never".into()),
                    tr.iters_to_reach(tol)
                        .map(|i| i.to_string())
                        .unwrap_or_else(|| "never".into()),
                    format!("{:.2e}", tr.final_error()),
                    format!("{:.4}", tr.sim_seconds()),
                    format!("{:.3}", tr.wall_seconds),
                    tr.messages().to_string(),
                    tr.drops.to_string(),
                ]);
                for (k, (&e, &s)) in
                    tr.errors().iter().zip(&tr.times()).enumerate()
                {
                    csv.push(vec![
                        kind.to_cli_name(),
                        k.to_string(),
                        format!("{s:.6e}"),
                        format!("{e:.6e}"),
                    ]);
                }
            }
            let path = format!(
                "{out_dir}/simnet_{}_{}_{}_n{n}.csv",
                scenario.label(),
                mode.label(),
                exec.label()
            );
            basegraph::util::write_csv(
                &path,
                &["topology", "iter", "seconds", "error"],
                &csv,
            )
            .map_err(|e| e.to_string())?;
            let t_head = format!("t→{tol:.0e} (s)");
            print_table(
                &format!(
                    "simnet consensus — scenario {}, mode {}, executor {}, \
                     n={n} (CSV: {path})",
                    scenario.label(),
                    mode.label(),
                    exec.label()
                ),
                &[
                    "topology",
                    "max deg",
                    t_head.as_str(),
                    "iters",
                    "err@end",
                    "sim s",
                    "wall s",
                    "msgs",
                    "drops",
                ],
                &rows,
            );
            Ok(())
        }
        "train" => {
            let rounds = args.usize_or("rounds", 100)?;
            let lr = args.f64_or("lr", 0.5)?;
            let dirichlet = args.f64_or("dirichlet", 10.0)?;
            let target = args.f64_or("target-acc", 0.6)?;
            let momentum = args.f64_or("momentum", 0.9)? as f32;
            let optimizer = OptimizerKind::parse(
                &args.str_or("optimizer", "dsgdm"),
                momentum,
            )?;
            let engine =
                Engine::parse(&args.str_or("engine", "native-linear"))?;
            let workload = classification_workload(&engine, seed)?;
            let mut rows = Vec::new();
            let mut csv = Vec::new();
            for t in &topos {
                let kind = TopologyKind::parse(t)?;
                let res = match churn {
                    Some(spec) => {
                        let schedule =
                            churn_schedule(&kind, n, rounds, spec)?;
                        run_training_exec_elastic(
                            &workload,
                            &schedule,
                            dirichlet,
                            optimizer,
                            lr,
                            seed,
                            &exec,
                            &ckpt.scoped(t),
                            &tsession.run(t)?,
                            codec,
                        )?
                    }
                    None => run_training_exec_codec_tel(
                        &workload, kind, n, dirichlet, optimizer, rounds,
                        lr, seed, &exec, &ckpt.scoped(t),
                        &tsession.run(t)?, codec,
                    )?,
                };
                let tta = res.run.time_to_accuracy(target);
                rows.push(vec![
                    kind.label(),
                    tta.map(|t| format!("{:.4}", t.sim_seconds))
                        .unwrap_or_else(|| "never".into()),
                    tta.map(|t| format!("{:.1}", t.cum_bytes as f64 / 1e6))
                        .unwrap_or_else(|| "-".into()),
                    format!("{:.2}", 100.0 * res.run.best_acc()),
                    format!("{:.4}", res.ledger.sim_seconds),
                    format!("{:.1}", res.ledger.bytes as f64 / 1e6),
                    res.drops.to_string(),
                ]);
                csv.push(vec![
                    kind.to_cli_name(),
                    tta.map(|t| format!("{:.6e}", t.sim_seconds))
                        .unwrap_or_else(|| "inf".into()),
                    tta.map(|t| t.cum_bytes.to_string())
                        .unwrap_or_else(|| "inf".into()),
                    format!("{:.4}", res.run.best_acc()),
                    format!("{:.6e}", res.ledger.sim_seconds),
                    res.ledger.bytes.to_string(),
                    res.drops.to_string(),
                ]);
            }
            let path = format!(
                "{out_dir}/simnet_train_{}_{}_{}_n{n}.csv",
                scenario.label(),
                mode.label(),
                exec.label()
            );
            basegraph::util::write_csv(
                &path,
                &[
                    "topology",
                    "seconds_to_target",
                    "bytes_to_target",
                    "best_acc",
                    "sim_seconds",
                    "bytes",
                    "drops",
                ],
                &csv,
            )
            .map_err(|e| e.to_string())?;
            println!("CSV: {path}");
            print_table(
                &format!(
                    "simnet training — scenario {}, mode {}, executor {}, \
                     n={n}, {} rounds, target acc {:.0}%",
                    scenario.label(),
                    mode.label(),
                    exec.label(),
                    rounds,
                    100.0 * target
                ),
                &[
                    "topology",
                    "t→target (s)",
                    "MB→target",
                    "best acc %",
                    "sim s",
                    "comm MB",
                    "drops",
                ],
                &rows,
            );
            Ok(())
        }
        other => Err(format!(
            "unknown simnet workload {other:?} (consensus|train)"
        )),
    }
}

/// `basegraph bench`: the round-engine perf harness behind the BENCH
/// trajectory. Times rounds/sec and bytes/round for the consensus and
/// training workloads over Base-4, at every (n, d) in the grid, on the
/// analytic and threaded backends — each cell run twice: once through
/// the scratch-buffer pipeline (the shipping engine) and once through
/// [`AllocatingWorkload`], which hides the scratch overrides and restores
/// the legacy clone-per-round path. The per-cell `speedup` column is the
/// allocation churn's measured price. Process-backend cells
/// (`--shards-list`, default 2 and 4 worker processes) run each workload
/// over real sockets and add the measured `wire_bytes_per_round` column.
/// Kernel cells A/B the SIMD dispatch (forced scalar vs auto) per
/// workload at d ∈ {1k, 100k, 1M}. Results land in `--out`
/// (`BENCH_rounds.json`).
fn cmd_bench(args: &Args) -> Result<(), String> {
    let out = args.str_or("out", "BENCH_rounds.json");
    let fast = args.flag("fast");
    let seed = args.u64_or("seed", 42)?;
    let rounds = args.usize_or("rounds", 20)?;
    let def_ns: &[usize] = if fast { &[64] } else { &[64, 256] };
    let def_ds: &[usize] = if fast { &[1_000] } else { &[1_000, 100_000] };
    let def_shards: &[usize] = if fast { &[2] } else { &[2, 4] };
    let ns = args.usize_list_or("ns", def_ns)?;
    let ds = args.usize_list_or("ds", def_ds)?;
    let shards_list = args.usize_list_or("shards-list", def_shards)?;
    // Gossip-codec roster for the codec cells (`--codec a,b,c`
    // restricts it; default = every built-in codec).
    let codecs: Vec<Codec> = match args.get("codec") {
        None => Codec::all_default(),
        Some(_) => args
            .str_list_or("codec", &[])
            .iter()
            .map(|s| Codec::parse(s))
            .collect::<Result<_, _>>()?,
    };
    if rounds == 0 {
        return Err("--rounds must be >= 1".into());
    }
    // One telemetry session for the whole grid; each cell gets its own
    // scoped NDJSON stream (the alloc passes stay untelemetered so the
    // engine-rate comparison is not perturbed on one side only).
    let tsession = TelemetryConfig::from_args(args).session()?;

    let mut cells = Vec::new();
    let mut rows = Vec::new();
    for &n in &ns {
        for &d in &ds {
            for backend in ["analytic", "threaded"] {
                for workload in ["consensus", "train"] {
                    let kind = TopologyKind::Base { m: 4 };
                    let seq = kind.build(n, seed)?;
                    let exec = ExecutorKind::parse(backend)?;
                    let tele = tsession
                        .run(&format!("{workload}_n{n}_d{d}_{backend}"))?;
                    let run = |alloc: bool| -> Result<ExecTrace, String> {
                        if workload == "consensus" {
                            let mut rng = Rng::new(seed);
                            let init = consensus::gaussian_init(
                                n, d, &mut rng,
                            );
                            if alloc {
                                let mut w = AllocatingWorkload::new(
                                    ConsensusWorkload::new(init),
                                );
                                exec.run(&mut w, &seq, rounds)
                            } else {
                                let mut w = ConsensusWorkload::new(init);
                                exec.run_tel(
                                    &mut w,
                                    &seq,
                                    rounds,
                                    &CkptConfig::default(),
                                    &tele,
                                )
                            }
                        } else {
                            let cfg = TrainConfig {
                                rounds,
                                lr: 0.05,
                                warmup: 0,
                                cosine: false,
                                optimizer: OptimizerKind::Dsgdm {
                                    momentum: 0.9,
                                },
                                eval_every: 0,
                                threads: 0,
                                cost: CostModel::default(),
                            };
                            let (model, data) =
                                quadratic_fixed_targets(n, d, seed);
                            if alloc {
                                let mut w = AllocatingWorkload::new(
                                    TrainingWorkload::new(
                                        &model, &cfg, data, &[],
                                    ),
                                );
                                exec.run(&mut w, &seq, rounds)
                            } else {
                                let mut w = TrainingWorkload::new(
                                    &model, &cfg, data, &[],
                                );
                                exec.run_tel(
                                    &mut w,
                                    &seq,
                                    rounds,
                                    &CkptConfig::default(),
                                    &tele,
                                )
                            }
                        }
                    };
                    // Rate of the round loop itself: per-record wall
                    // clocks bracket exactly the rounds between the
                    // first and last record, excluding the identical
                    // one-time setup (init_nodes clones the full n×d
                    // state) that would otherwise dilute the engine
                    // comparison. Falls back to the whole-run clock on
                    // degenerate traces.
                    let loop_rate = |tr: &ExecTrace| -> f64 {
                        let rec = &tr.run.records;
                        match (rec.first(), rec.last()) {
                            (Some(a), Some(b))
                                if b.round > a.round
                                    && b.wall_seconds > a.wall_seconds =>
                            {
                                (b.round - a.round) as f64
                                    / (b.wall_seconds - a.wall_seconds)
                            }
                            _ => {
                                rounds as f64 / tr.wall_seconds.max(1e-12)
                            }
                        }
                    };
                    // Two interleaved passes per engine, best rate kept:
                    // the first alloc pass warms page/file caches for
                    // everyone, so neither engine gets a cold-start
                    // penalty and one noisy sample cannot decide the
                    // speedup column.
                    let mut ta_wall = f64::INFINITY;
                    let mut ts_wall = f64::INFINITY;
                    let mut rps_a = 0.0f64;
                    let mut rps_s = 0.0f64;
                    let mut bpr = 0.0f64;
                    for _ in 0..2 {
                        let ta = run(true)?;
                        let ts = run(false)?;
                        rps_a = rps_a.max(loop_rate(&ta));
                        rps_s = rps_s.max(loop_rate(&ts));
                        ta_wall = ta_wall.min(ta.wall_seconds);
                        ts_wall = ts_wall.min(ts.wall_seconds);
                        bpr = ts.ledger.bytes as f64 / rounds as f64;
                    }
                    let speedup = rps_s / rps_a.max(1e-12);
                    rows.push(vec![
                        workload.to_string(),
                        n.to_string(),
                        d.to_string(),
                        backend.to_string(),
                        format!("{rps_a:.1}"),
                        format!("{rps_s:.1}"),
                        format!("{speedup:.2}×"),
                        format!("{:.2}", bpr / 1e6),
                    ]);
                    cells.push(Json::obj(vec![
                        ("workload", Json::str(workload)),
                        ("topology", Json::str("base-4")),
                        ("n", Json::num(n as f64)),
                        ("d", Json::num(d as f64)),
                        ("backend", Json::str(backend)),
                        ("rounds", Json::num(rounds as f64)),
                        ("wall_seconds_alloc", Json::num(ta_wall)),
                        ("wall_seconds_scratch", Json::num(ts_wall)),
                        ("rounds_per_sec_alloc", Json::num(rps_a)),
                        ("rounds_per_sec_scratch", Json::num(rps_s)),
                        ("speedup", Json::num(speedup)),
                        ("bytes_per_round", Json::num(bpr)),
                    ]));
                }
            }
        }
    }

    // Process-backend cells: the only backend with real IPC cost, so its
    // cells carry a measured wire_bytes_per_round column next to the α–β
    // model's bytes_per_round. One d per n (the first in the grid) keeps
    // worker-spawn overhead bounded; the alloc/scratch duality does not
    // apply (workers always run the scratch engine), so those fields are
    // null — trend gates skip null-valued columns.
    let d = *ds.first().ok_or("--ds must name at least one d")?;
    for &n in &ns {
        for &shards in &shards_list {
            for workload in ["consensus", "train"] {
                let kind = TopologyKind::Base { m: 4 };
                let seq = kind.build(n, seed)?;
                let exec = ExecutorKind::process(shards);
                let tele = tsession
                    .run(&format!("{workload}_n{n}_process{shards}"))?;
                let run = || -> Result<ExecTrace, String> {
                    if workload == "consensus" {
                        let mut rng = Rng::new(seed);
                        let init = consensus::gaussian_init(n, d, &mut rng);
                        let mut w = ConsensusWorkload::new(init);
                        exec.run_tel(
                            &mut w,
                            &seq,
                            rounds,
                            &CkptConfig::default(),
                            &tele,
                        )
                    } else {
                        let cfg = TrainConfig {
                            rounds,
                            lr: 0.05,
                            warmup: 0,
                            cosine: false,
                            optimizer: OptimizerKind::Dsgdm {
                                momentum: 0.9,
                            },
                            eval_every: 0,
                            threads: 0,
                            cost: CostModel::default(),
                        };
                        let (model, data) =
                            quadratic_fixed_targets(n, d, seed);
                        let mut w =
                            TrainingWorkload::new(&model, &cfg, data, &[])
                                .with_wire(
                                    basegraph::exec::TrainSpec::Quadratic {
                                        d,
                                        seed,
                                    },
                                );
                        exec.run_tel(
                            &mut w,
                            &seq,
                            rounds,
                            &CkptConfig::default(),
                            &tele,
                        )
                    }
                };
                // Per-record wall clocks bracket the round loop, which
                // excludes the (identical) spawn + handshake setup; two
                // passes, best rate kept, as for the in-process cells.
                let loop_rate = |tr: &ExecTrace| -> f64 {
                    let rec = &tr.run.records;
                    match (rec.first(), rec.last()) {
                        (Some(a), Some(b))
                            if b.round > a.round
                                && b.wall_seconds > a.wall_seconds =>
                        {
                            (b.round - a.round) as f64
                                / (b.wall_seconds - a.wall_seconds)
                        }
                        _ => rounds as f64 / tr.wall_seconds.max(1e-12),
                    }
                };
                let mut rps = 0.0f64;
                let mut wall = f64::INFINITY;
                let mut bpr = 0.0f64;
                let mut wire_bpr = 0.0f64;
                for _ in 0..2 {
                    let tr = run()?;
                    rps = rps.max(loop_rate(&tr));
                    wall = wall.min(tr.wall_seconds);
                    bpr = tr.ledger.bytes as f64 / rounds as f64;
                    wire_bpr =
                        tr.ledger.bytes_on_wire as f64 / rounds as f64;
                }
                rows.push(vec![
                    workload.to_string(),
                    n.to_string(),
                    d.to_string(),
                    format!("process×{shards}"),
                    "-".to_string(),
                    format!("{rps:.1}"),
                    "-".to_string(),
                    format!("{:.2}", wire_bpr / 1e6),
                ]);
                cells.push(Json::obj(vec![
                    ("workload", Json::str(workload)),
                    ("topology", Json::str("base-4")),
                    ("n", Json::num(n as f64)),
                    ("d", Json::num(d as f64)),
                    ("backend", Json::str("process")),
                    ("shards", Json::num(shards as f64)),
                    ("rounds", Json::num(rounds as f64)),
                    ("wall_seconds_alloc", Json::Null),
                    ("wall_seconds_scratch", Json::num(wall)),
                    ("rounds_per_sec_alloc", Json::Null),
                    ("rounds_per_sec_scratch", Json::num(rps)),
                    ("speedup", Json::Null),
                    ("bytes_per_round", Json::num(bpr)),
                    ("wire_bytes_per_round", Json::num(wire_bpr)),
                ]));
            }
        }
    }

    // Simnet cells: the same workloads driven through the event-driven
    // network simulator under the `lan` scenario — this times the BSP
    // event loop (queue churn, per-link latency draws) rather than the
    // bare lock-step engine, which none of the other cells cover. The
    // alloc/scratch duality does not apply (the simulator always runs
    // the scratch engine), so those columns are null — the trend gate
    // compares the scratch rate only — and the α–β column is joined by
    // the scenario's virtual clock (`sim_seconds`).
    for &n in &ns {
        for workload in ["consensus", "train"] {
            let kind = TopologyKind::Base { m: 4 };
            let seq = kind.build(n, seed)?;
            let exec = ExecutorKind::parse("simnet")?
                .with_sim(Scenario::Lan.config(seed));
            let tele =
                tsession.run(&format!("{workload}_n{n}_simnet_lan"))?;
            let run = || -> Result<ExecTrace, String> {
                if workload == "consensus" {
                    let mut rng = Rng::new(seed);
                    let init = consensus::gaussian_init(n, d, &mut rng);
                    let mut w = ConsensusWorkload::new(init);
                    exec.run_tel(
                        &mut w,
                        &seq,
                        rounds,
                        &CkptConfig::default(),
                        &tele,
                    )
                } else {
                    let cfg = TrainConfig {
                        rounds,
                        lr: 0.05,
                        warmup: 0,
                        cosine: false,
                        optimizer: OptimizerKind::Dsgdm { momentum: 0.9 },
                        eval_every: 0,
                        threads: 0,
                        cost: CostModel::default(),
                    };
                    let (model, data) = quadratic_fixed_targets(n, d, seed);
                    let mut w =
                        TrainingWorkload::new(&model, &cfg, data, &[]);
                    exec.run_tel(
                        &mut w,
                        &seq,
                        rounds,
                        &CkptConfig::default(),
                        &tele,
                    )
                }
            };
            let loop_rate = |tr: &ExecTrace| -> f64 {
                let rec = &tr.run.records;
                match (rec.first(), rec.last()) {
                    (Some(a), Some(b))
                        if b.round > a.round
                            && b.wall_seconds > a.wall_seconds =>
                    {
                        (b.round - a.round) as f64
                            / (b.wall_seconds - a.wall_seconds)
                    }
                    _ => rounds as f64 / tr.wall_seconds.max(1e-12),
                }
            };
            let mut rps = 0.0f64;
            let mut wall = f64::INFINITY;
            let mut bpr = 0.0f64;
            let mut sim_s = 0.0f64;
            for _ in 0..2 {
                let tr = run()?;
                rps = rps.max(loop_rate(&tr));
                wall = wall.min(tr.wall_seconds);
                bpr = tr.ledger.bytes as f64 / rounds as f64;
                sim_s = tr.ledger.sim_seconds;
            }
            rows.push(vec![
                workload.to_string(),
                n.to_string(),
                d.to_string(),
                "simnet (lan)".to_string(),
                "-".to_string(),
                format!("{rps:.1}"),
                "-".to_string(),
                format!("{:.2}", bpr / 1e6),
            ]);
            cells.push(Json::obj(vec![
                ("workload", Json::str(workload)),
                ("topology", Json::str("base-4")),
                ("n", Json::num(n as f64)),
                ("d", Json::num(d as f64)),
                ("backend", Json::str("simnet")),
                ("scenario", Json::str("lan")),
                ("rounds", Json::num(rounds as f64)),
                ("wall_seconds_alloc", Json::Null),
                ("wall_seconds_scratch", Json::num(wall)),
                ("rounds_per_sec_alloc", Json::Null),
                ("rounds_per_sec_scratch", Json::num(rps)),
                ("speedup", Json::Null),
                ("bytes_per_round", Json::num(bpr)),
                ("sim_seconds", Json::num(sim_s)),
            ]));
        }
    }

    // Codec cells: the training workload re-run once per gossip codec on
    // the analytic backend — rounds/sec prices the source transform +
    // error-feedback pass, bytes_per_round is the codec-compressed byte
    // charge (the Pareto axis the repro simnet sweep plots). The
    // alloc/scratch duality does not apply, so those columns are null
    // and trend gates skip them; cells are keyed by their `codec` field.
    for &n in &ns {
        for &codec in &codecs {
            let kind = TopologyKind::Base { m: 4 };
            let seq = kind.build(n, seed)?;
            let exec = ExecutorKind::parse("analytic")?;
            let tele = tsession
                .run(&format!("train_n{n}_codec_{}", codec.label()))?;
            let run = || -> Result<ExecTrace, String> {
                let cfg = TrainConfig {
                    rounds,
                    lr: 0.05,
                    warmup: 0,
                    cosine: false,
                    optimizer: OptimizerKind::Dsgdm { momentum: 0.9 },
                    eval_every: 0,
                    threads: 0,
                    cost: CostModel::default(),
                };
                let (model, data) = quadratic_fixed_targets(n, d, seed);
                let mut w = TrainingWorkload::new(&model, &cfg, data, &[])
                    .with_codec(codec);
                exec.run_tel(
                    &mut w,
                    &seq,
                    rounds,
                    &CkptConfig::default(),
                    &tele,
                )
            };
            let loop_rate = |tr: &ExecTrace| -> f64 {
                let rec = &tr.run.records;
                match (rec.first(), rec.last()) {
                    (Some(a), Some(b))
                        if b.round > a.round
                            && b.wall_seconds > a.wall_seconds =>
                    {
                        (b.round - a.round) as f64
                            / (b.wall_seconds - a.wall_seconds)
                    }
                    _ => rounds as f64 / tr.wall_seconds.max(1e-12),
                }
            };
            let mut rps = 0.0f64;
            let mut wall = f64::INFINITY;
            let mut bpr = 0.0f64;
            for _ in 0..2 {
                let tr = run()?;
                rps = rps.max(loop_rate(&tr));
                wall = wall.min(tr.wall_seconds);
                bpr = tr.ledger.bytes as f64 / rounds as f64;
            }
            rows.push(vec![
                "train".to_string(),
                n.to_string(),
                d.to_string(),
                format!("analytic {}", codec.label()),
                "-".to_string(),
                format!("{rps:.1}"),
                "-".to_string(),
                format!("{:.2}", bpr / 1e6),
            ]);
            cells.push(Json::obj(vec![
                ("workload", Json::str("train")),
                ("topology", Json::str("base-4")),
                ("n", Json::num(n as f64)),
                ("d", Json::num(d as f64)),
                ("backend", Json::str("analytic")),
                ("codec", Json::str(&codec.label())),
                ("rounds", Json::num(rounds as f64)),
                ("wall_seconds_alloc", Json::Null),
                ("wall_seconds_scratch", Json::num(wall)),
                ("rounds_per_sec_alloc", Json::Null),
                ("rounds_per_sec_scratch", Json::num(rps)),
                ("speedup", Json::Null),
                ("bytes_per_round", Json::num(bpr)),
            ]));
        }
    }

    // Kernel A/B cells: the same workloads with the SIMD dispatch pinned
    // to the scalar reference vs. `auto` (the best vector path this CPU
    // has), on the serial analytic backend so the kernel is the only
    // variable. Dimensions are fixed at {1k, 100k, 1M} regardless of
    // --ds (the point is the d-scaling of the combine loop, and these
    // cells run in --fast mode too); results are bit-identical by the
    // kernel contract, so only the rate may differ. `kernel_speedup` is
    // auto/scalar; on a CPU with no vector path both sides run scalar
    // and the column hovers at 1.
    {
        use basegraph::kernels;
        let kn = 16usize;
        for &kd in &[1_000usize, 100_000, 1_000_000] {
            for workload in ["consensus", "train"] {
                let kind = TopologyKind::Base { m: 4 };
                let seq = kind.build(kn, seed)?;
                let exec = ExecutorKind::parse("analytic")?;
                let run = |path: kernels::Path| -> Result<ExecTrace, String> {
                    kernels::with_forced(path, || {
                        if workload == "consensus" {
                            let mut rng = Rng::new(seed);
                            let init =
                                consensus::gaussian_init(kn, kd, &mut rng);
                            let mut w = ConsensusWorkload::new(init);
                            exec.run(&mut w, &seq, rounds)
                        } else {
                            let cfg = TrainConfig {
                                rounds,
                                lr: 0.05,
                                warmup: 0,
                                cosine: false,
                                optimizer: OptimizerKind::Dsgdm {
                                    momentum: 0.9,
                                },
                                eval_every: 0,
                                threads: 1,
                                cost: CostModel::default(),
                            };
                            let (model, data) =
                                quadratic_fixed_targets(kn, kd, seed);
                            let mut w = TrainingWorkload::new(
                                &model, &cfg, data, &[],
                            );
                            exec.run(&mut w, &seq, rounds)
                        }
                    })
                };
                let loop_rate = |tr: &ExecTrace| -> f64 {
                    let rec = &tr.run.records;
                    match (rec.first(), rec.last()) {
                        (Some(a), Some(b))
                            if b.round > a.round
                                && b.wall_seconds > a.wall_seconds =>
                        {
                            (b.round - a.round) as f64
                                / (b.wall_seconds - a.wall_seconds)
                        }
                        _ => rounds as f64 / tr.wall_seconds.max(1e-12),
                    }
                };
                let mut rps_scalar = 0.0f64;
                let mut rps_auto = 0.0f64;
                for _ in 0..2 {
                    let ts = run(kernels::Path::Scalar)?;
                    let ta = run(kernels::auto_path())?;
                    rps_scalar = rps_scalar.max(loop_rate(&ts));
                    rps_auto = rps_auto.max(loop_rate(&ta));
                }
                let kernel_speedup = rps_auto / rps_scalar.max(1e-12);
                rows.push(vec![
                    workload.to_string(),
                    kn.to_string(),
                    kd.to_string(),
                    format!("kernels {}", kernels::vector_label()),
                    format!("{rps_scalar:.1}"),
                    format!("{rps_auto:.1}"),
                    format!("{kernel_speedup:.2}×"),
                    "-".to_string(),
                ]);
                cells.push(Json::obj(vec![
                    ("workload", Json::str(workload)),
                    ("topology", Json::str("base-4")),
                    ("n", Json::num(kn as f64)),
                    ("d", Json::num(kd as f64)),
                    ("backend", Json::str("analytic")),
                    ("kernels", Json::str("ab")),
                    ("vector", Json::str(kernels::vector_label())),
                    ("rounds", Json::num(rounds as f64)),
                    ("rounds_per_sec_scalar", Json::num(rps_scalar)),
                    ("rounds_per_sec_auto", Json::num(rps_auto)),
                    ("kernel_speedup", Json::num(kernel_speedup)),
                ]));
            }
        }
    }

    let doc = Json::obj(vec![
        ("name", Json::str("BENCH_rounds")),
        (
            "generated_by",
            Json::str("basegraph bench (alloc = legacy allocating engine \
                       via AllocatingWorkload, scratch = shipping \
                       zero-allocation engine; kernels cells A/B the \
                       scalar vs auto SIMD dispatch)"),
        ),
        ("seed", Json::num(seed as f64)),
        ("kernels_vector", Json::str(basegraph::kernels::vector_label())),
        ("cells", Json::arr(cells)),
    ]);
    if let Some(dir) = std::path::Path::new(&out).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
        }
    }
    std::fs::write(&out, json::write(&doc) + "\n")
        .map_err(|e| format!("write {out}: {e}"))?;
    print_table(
        &format!("round-engine bench, {rounds} rounds/cell (JSON: {out})"),
        &[
            "workload",
            "n",
            "d",
            "backend",
            "rounds/s alloc|scalar",
            "rounds/s scratch|auto",
            "speedup",
            "MB/round",
        ],
        &rows,
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<(), String> {
    let dir = args.str_or("artifacts", "artifacts");
    match basegraph::runtime::Manifest::load(&dir) {
        Ok(m) => {
            let rows: Vec<Vec<String>> = m
                .models
                .iter()
                .map(|e| {
                    vec![
                        e.name.clone(),
                        e.variant.clone(),
                        e.d_params.to_string(),
                        format!("{:?}", e.train.x_shape),
                        e.train.hlo.clone(),
                    ]
                })
                .collect();
            print_table(
                &format!("artifacts in {dir}"),
                &["model", "variant", "D", "train x", "hlo"],
                &rows,
            );
            println!("{} mixing kernels", m.mix.len());
        }
        Err(e) => {
            println!("no artifacts loaded ({e}); native engines still work");
        }
    }
    Ok(())
}
