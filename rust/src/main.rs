//! `basegraph` — the command-line launcher for the BaseGraph reproduction.
//!
//! Subcommands:
//!   topology   inspect/validate a topology (length, degree, finite-time, β)
//!   list       print every buildable topology with its max degree at some n
//!   consensus  run the Sec. 6.1 consensus experiment and dump CSV
//!   train      run one decentralized training job (native or PJRT engine)
//!   repro      regenerate a paper table/figure (see DESIGN.md index)
//!   info       show the artifacts manifest and runtime status
//!
//! Run `basegraph <cmd> --help` for per-command flags.

use basegraph::consensus;
use basegraph::optim::OptimizerKind;
use basegraph::repro;
use basegraph::repro::common::{
    classification_workload, print_table, run_training, Engine,
};
use basegraph::topology::{self, TopologyKind};
use basegraph::util::cli::Args;
use basegraph::util::rng::Rng;

const USAGE: &str = "\
basegraph — Base-(k+1) Graph reproduction (NeurIPS 2023)

USAGE:
  basegraph topology  --kind <name> --n <n> [--seed S] [--validate]
  basegraph list      [--n N] [--seed S]
  basegraph consensus --n <n> [--iters I] [--topos a,b,c] [--out results]
  basegraph train     --topo <name> --n <n> [--alpha A] [--rounds R]
                      [--lr LR] [--optimizer dsgd|dsgdm|qg-dsgdm|d2|gt]
                      [--engine native-mlp|native-linear|pjrt:mlp:ref]
                      [--out results]
  basegraph repro     --exp <id> [--fast] [--engine E] [--n N] [--ns a,b]
                      [--rounds R] [--seed S] [--out results]
  basegraph info      [--artifacts DIR]

Topology names: ring, torus, exp, onepeer-exp, onepeer-hypercube, complete,
  base-<m>, simple-base-<m>, hh-<k>, u-equidyn, d-equidyn,
  u-equistatic-<deg>, d-equistatic-<deg>  (`basegraph list` enumerates them).
Experiments: table1 table2 fig5 fig6 fig7 fig8 fig9 fig21 fig22 fig23
  fig25 fig26 frontier all";

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if raw.is_empty() || raw[0] == "--help" || raw[0] == "help" {
        println!("{USAGE}");
        return;
    }
    let cmd = raw[0].clone();
    let args = match Args::parse(&raw[1..], &["validate", "fast", "help"]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if args.flag("help") {
        println!("{USAGE}");
        return;
    }
    let result = match cmd.as_str() {
        "topology" => cmd_topology(&args),
        "list" => cmd_list(&args),
        "consensus" => cmd_consensus(&args),
        "train" => cmd_train(&args),
        "repro" => repro::run(&args),
        "info" => cmd_info(&args),
        other => Err(format!("unknown command {other:?}\n\n{USAGE}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn cmd_topology(args: &Args) -> Result<(), String> {
    let kind = TopologyKind::parse(&args.str_or("kind", "base-2"))?;
    let n = args.usize_or("n", 25)?;
    let seed = args.u64_or("seed", 0)?;
    let seq = kind.build(n, seed)?;
    let mut rng = Rng::new(seed);
    // Spectral β and the finite-time product need the dense view (O(n²)
    // memory, O(n³) work) — skip them at scale, where the sparse plan is
    // the whole point.
    let (beta, finite) = if n <= 1024 {
        // One product serves both checks (it is the dominant cost here).
        let prod = seq.product();
        let beta = prod.consensus_rate(300, &mut rng);
        let finite = prod
            .max_abs_diff(&basegraph::MixingMatrix::average(seq.n))
            <= 1e-9;
        (format!("{beta:.6}"), finite.to_string())
    } else {
        ("skipped (n>1024)".into(), "skipped (n>1024)".into())
    };
    let rows = vec![vec![
        kind.label(),
        n.to_string(),
        seq.len().to_string(),
        seq.max_degree().to_string(),
        finite,
        beta,
    ]];
    print_table(
        "topology",
        &["name", "n", "phases", "max deg", "finite-time", "sweep β"],
        &rows,
    );
    if args.flag("validate") {
        for (i, p) in seq.phases.iter().enumerate() {
            // Sparse O(edges) check — no dense matrix even at large n.
            if !p.is_doubly_stochastic(1e-9) {
                return Err(format!("phase {i} is not doubly stochastic"));
            }
        }
        println!(
            "validation OK: all phases doubly stochastic; degree ≤ {}",
            seq.max_degree()
        );
    }
    Ok(())
}

/// `basegraph list`: every buildable topology at `--n`, with its CLI name,
/// phase count, max degree and per-sweep message count — or the reason it
/// cannot be built at that n.
fn cmd_list(args: &Args) -> Result<(), String> {
    let n = args.usize_or("n", 25)?;
    let seed = args.u64_or("seed", 0)?;
    let mut rows = Vec::new();
    for kind in topology::catalog() {
        let row = match kind.build(n, seed) {
            Ok(seq) => {
                let msgs: usize =
                    seq.phases.iter().map(|p| p.messages()).sum();
                vec![
                    kind.to_cli_name(),
                    kind.label(),
                    seq.len().to_string(),
                    seq.max_degree().to_string(),
                    msgs.to_string(),
                ]
            }
            Err(e) => vec![
                kind.to_cli_name(),
                kind.label(),
                "-".into(),
                "-".into(),
                format!("unavailable: {e}"),
            ],
        };
        rows.push(row);
    }
    print_table(
        &format!("topologies at n={n}"),
        &["cli name", "label", "phases", "max deg", "msgs/sweep"],
        &rows,
    );
    Ok(())
}

fn cmd_consensus(args: &Args) -> Result<(), String> {
    let n = args.usize_or("n", 25)?;
    let iters = args.usize_or("iters", 60)?;
    let seed = args.u64_or("seed", 42)?;
    let out_dir = args.str_or("out", "results");
    let topos = args.str_list_or(
        "topos",
        &["ring", "exp", "onepeer-exp", "base-2", "base-4"],
    );
    std::fs::create_dir_all(&out_dir).map_err(|e| e.to_string())?;
    let mut rows = Vec::new();
    let mut header = vec!["iter".to_string()];
    let mut series = Vec::new();
    for t in &topos {
        let kind = TopologyKind::parse(t)?;
        let seq = kind.build(n, seed)?;
        let trace = consensus::paper_consensus_experiment(&seq, iters, seed);
        header.push(kind.label());
        rows.push(vec![
            kind.label(),
            seq.max_degree().to_string(),
            trace
                .iters_to_reach(1e-20)
                .map(|i| i.to_string())
                .unwrap_or_else(|| "never".into()),
            format!("{:.3e}", trace.errors[iters]),
        ]);
        series.push(trace.errors);
    }
    let csv_rows: Vec<Vec<String>> = (0..=iters)
        .map(|it| {
            let mut row = vec![it.to_string()];
            for s in &series {
                row.push(format!("{:.6e}", s[it]));
            }
            row
        })
        .collect();
    let path = format!("{out_dir}/consensus_n{n}.csv");
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    basegraph::util::write_csv(&path, &header_refs, &csv_rows)
        .map_err(|e| e.to_string())?;
    print_table(
        &format!("consensus at n={n} (CSV: {path})"),
        &["topology", "max deg", "iters to exact", "err@end"],
        &rows,
    );
    Ok(())
}

fn cmd_train(args: &Args) -> Result<(), String> {
    let kind = TopologyKind::parse(&args.str_or("topo", "base-2"))?;
    let n = args.usize_or("n", 25)?;
    let alpha = args.f64_or("alpha", 0.1)?;
    let rounds = args.usize_or("rounds", 200)?;
    let lr = args.f64_or("lr", 0.5)?;
    let seed = args.u64_or("seed", 42)?;
    let momentum = args.f64_or("momentum", 0.9)? as f32;
    let optimizer =
        OptimizerKind::parse(&args.str_or("optimizer", "dsgdm"), momentum)?;
    let engine = Engine::parse(&args.str_or("engine", "native-mlp"))?;
    let out_dir = args.str_or("out", "results");
    std::fs::create_dir_all(&out_dir).map_err(|e| e.to_string())?;

    let workload = classification_workload(&engine, seed)?;
    println!(
        "training {} on {} (n={n}, α={alpha}, {} rounds, lr={lr}, {})",
        workload.provider.name(),
        kind.label(),
        rounds,
        optimizer.label()
    );
    let res =
        run_training(&workload, kind, n, alpha, optimizer, rounds, lr, seed)?;
    let path = format!(
        "{out_dir}/train_{}_n{n}.csv",
        args.str_or("topo", "base-2")
    );
    res.write_csv(&path).map_err(|e| e.to_string())?;
    let evals: Vec<Vec<String>> = res
        .records
        .iter()
        .filter(|r| !r.test_acc.is_nan())
        .map(|r| {
            vec![
                r.round.to_string(),
                format!("{:.4}", r.train_loss),
                format!("{:.2}", 100.0 * r.test_acc),
                format!("{:.2e}", r.consensus_error),
                format!("{:.1}", r.cum_bytes as f64 / 1e6),
            ]
        })
        .collect();
    print_table(
        &format!("training curve (CSV: {path})"),
        &["round", "train loss", "test acc %", "consensus", "comm MB"],
        &evals,
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<(), String> {
    let dir = args.str_or("artifacts", "artifacts");
    match basegraph::runtime::Manifest::load(&dir) {
        Ok(m) => {
            let rows: Vec<Vec<String>> = m
                .models
                .iter()
                .map(|e| {
                    vec![
                        e.name.clone(),
                        e.variant.clone(),
                        e.d_params.to_string(),
                        format!("{:?}", e.train.x_shape),
                        e.train.hlo.clone(),
                    ]
                })
                .collect();
            print_table(
                &format!("artifacts in {dir}"),
                &["model", "variant", "D", "train x", "hlo"],
                &rows,
            );
            println!("{} mixing kernels", m.mix.len());
        }
        Err(e) => {
            println!("no artifacts loaded ({e}); native engines still work");
        }
    }
    Ok(())
}
