//! Checkpoint/resume: round-boundary snapshots with a deterministic
//! replay contract.
//!
//! A [`Snapshot`] captures everything a run needs to continue
//! **bit-exactly** from a round boundary: per-node workload states
//! (opaque byte blobs produced by
//! [`Workload::node_ckpt`](crate::exec::Workload::node_ckpt)), the
//! [`CommLedger`] (including measured `bytes_on_wire` and the simulated
//! clock), the record prefix of the eventual
//! [`ExecTrace`](crate::exec::ExecTrace), and — for the event-driven
//! backend — the virtual clock plus the network RNG cursor. The on-disk
//! format follows the wire-protocol conventions of `exec/wire.rs`: a
//! magic byte, a version byte, a frame kind, a little-endian length,
//! exact f64/f32 bit patterns in the body, and a CRC-32 over the body.
//!
//! ```text
//!  offset  size  field
//!  0       1     CKPT_MAGIC (0xC6)
//!  1       1     CKPT_VERSION (1)
//!  2       1     kind (KIND_SNAPSHOT = 1)
//!  3       4     body length, u32 LE
//!  7       len   body (ByteWriter layout, exact bit patterns)
//!  7+len   4     CRC-32 over the body, u32 LE
//! ```
//!
//! Corruption is a **typed** error ([`CkptError`]), never a panic or
//! silent garbage: wrong magic, wrong version, truncation at any offset
//! and a flipped body byte each map to their own variant — mirroring the
//! wire-protocol negative tests.
//!
//! # Determinism contract
//!
//! A run checkpointed at round *r* and resumed from that snapshot is
//! bit-identical to the uninterrupted run — final states, records, and
//! the ledger's model columns (`messages`, `bytes`, `sim_seconds`,
//! `rounds`). The *measured* columns (`wall_seconds`,
//! `bytes_on_wire` / `cum_wire_bytes`) are clocks and byte counters of
//! what physically happened, so a resumed process-backend run pays a
//! second handshake and its wire counter differs; everything the
//! arithmetic touches is pinned by `tests/exec_equivalence.rs`.

use std::path::{Path, PathBuf};

use crate::comm::CommLedger;
use crate::exec::wire::{crc32, ByteReader, ByteWriter};
use crate::metrics::RoundRecord;

/// First byte of every checkpoint file (the wire protocol uses 0xB6).
pub const CKPT_MAGIC: u8 = 0xC6;
/// Bump on any body-layout change; old snapshots then fail loudly with
/// [`CkptError::VersionMismatch`] instead of decoding garbage.
pub const CKPT_VERSION: u8 = 1;
/// Frame kind of a full run snapshot (room for future kinds).
pub const KIND_SNAPSHOT: u8 = 1;

/// Typed checkpoint-format errors — the contract of the negative tests:
/// every way a snapshot file can be wrong has a name.
#[derive(Debug, Clone, PartialEq)]
pub enum CkptError {
    /// First byte is not [`CKPT_MAGIC`] — not a checkpoint file.
    BadMagic(u8),
    /// A checkpoint written by a different format version.
    VersionMismatch { found: u8 },
    /// Unknown frame kind byte.
    BadKind(u8),
    /// The file ends before the declared layout does.
    Truncated { what: &'static str },
    /// CRC-32 over the body does not match the stored checksum.
    ChecksumMismatch,
    /// Header and checksum are fine but the body does not decode.
    Malformed(String),
    /// Filesystem-level failure (open/read/write/rename).
    Io(String),
}

impl std::fmt::Display for CkptError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CkptError::BadMagic(b) => write!(
                f,
                "bad checkpoint magic 0x{b:02X} (expected 0x{CKPT_MAGIC:02X} \
                 — not a basegraph checkpoint)"
            ),
            CkptError::VersionMismatch { found } => write!(
                f,
                "checkpoint format version mismatch: file is v{found}, \
                 this binary reads v{CKPT_VERSION}"
            ),
            CkptError::BadKind(k) => {
                write!(f, "unknown checkpoint frame kind {k}")
            }
            CkptError::Truncated { what } => write!(
                f,
                "truncated checkpoint ({what}): file ends before the \
                 declared layout does"
            ),
            CkptError::ChecksumMismatch => write!(
                f,
                "checkpoint checksum mismatch — the snapshot body is \
                 corrupt"
            ),
            CkptError::Malformed(e) => {
                write!(f, "malformed checkpoint body: {e}")
            }
            CkptError::Io(e) => write!(f, "checkpoint io: {e}"),
        }
    }
}

impl From<CkptError> for String {
    fn from(e: CkptError) -> String {
        e.to_string()
    }
}

/// Everything a run needs to continue bit-exactly from a round boundary.
///
/// `nodes[i]` is the opaque per-node state blob produced by
/// [`Workload::node_ckpt`](crate::exec::Workload::node_ckpt) — the
/// snapshot layer never interprets it, so new workloads get durable
/// snapshots by implementing two methods. `round` counts *completed*
/// rounds: a resumed run starts its loop at `round`.
#[derive(Debug, Clone)]
pub struct Snapshot {
    /// Topology name (`GraphSequence::name`); validated on resume.
    pub topology: String,
    pub n: usize,
    /// Rounds completed when the snapshot was taken; resume starts here.
    pub round: usize,
    /// Per-node workload state blobs, in node order (`len == n`).
    pub nodes: Vec<Vec<u8>>,
    /// Communication ledger at the boundary (model columns exact; the
    /// measured `bytes_on_wire` is a counter of what physically
    /// happened and restarts semantics on resume — see module docs).
    pub ledger: CommLedger,
    /// The record prefix of the eventual `ExecTrace`.
    pub records: Vec<RoundRecord>,
    /// Simnet BSP virtual clock at the boundary (0 elsewhere). The BSP
    /// event queue is empty at every round boundary by construction, so
    /// the clock plus the RNG cursor below *is* the full event state.
    pub clock: f64,
    /// Network RNG cursor (xoshiro256++ state words + the cached
    /// Box–Muller spare), present for snapshots taken by the simnet
    /// backend.
    pub rng: Option<([u64; 4], Option<f64>)>,
    /// Live roster at the boundary (ascending node ids within the fixed
    /// id capacity `0..n`), present for elastic-membership runs.
    /// Encoded as an optional tagged tail section, so `None`-roster
    /// snapshots stay byte-identical to the pre-elastic format and
    /// legacy files load as `None`.
    pub roster: Option<Vec<u32>>,
}

/// Optional snapshot tail section tag: the live roster.
pub const SNAP_TAG_ROSTER: u8 = 1;

fn put_record(w: &mut ByteWriter, r: &RoundRecord) {
    w.put_usize(r.round);
    w.put_f64(r.train_loss);
    w.put_f64(r.consensus_error);
    w.put_f64(r.test_loss);
    w.put_f64(r.test_acc);
    w.put_u64(r.cum_messages);
    w.put_u64(r.cum_bytes);
    w.put_u64(r.cum_wire_bytes);
    w.put_f64(r.sim_seconds);
    w.put_f64(r.wall_seconds);
}

fn get_record(r: &mut ByteReader) -> Result<RoundRecord, String> {
    Ok(RoundRecord {
        round: r.get_usize()?,
        train_loss: r.get_f64()?,
        consensus_error: r.get_f64()?,
        test_loss: r.get_f64()?,
        test_acc: r.get_f64()?,
        cum_messages: r.get_u64()?,
        cum_bytes: r.get_u64()?,
        cum_wire_bytes: r.get_u64()?,
        sim_seconds: r.get_f64()?,
        wall_seconds: r.get_f64()?,
        // Diagnostic kernel timing is not persisted (keeps the snapshot
        // format byte-identical to pre-kernel files).
        combine_ns: 0,
    })
}

impl Snapshot {
    /// Encode the snapshot as complete file bytes (header + body + CRC).
    pub fn to_file_bytes(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_str(&self.topology);
        w.put_usize(self.n);
        w.put_usize(self.round);
        w.put_u64(self.ledger.messages);
        w.put_u64(self.ledger.bytes);
        w.put_f64(self.ledger.sim_seconds);
        w.put_u64(self.ledger.rounds);
        w.put_u64(self.ledger.bytes_on_wire);
        w.put_f64(self.clock);
        match &self.rng {
            None => w.put_u8(0),
            Some((s, spare)) => {
                w.put_u8(1);
                for &word in s {
                    w.put_u64(word);
                }
                match spare {
                    None => w.put_u8(0),
                    Some(z) => {
                        w.put_u8(1);
                        w.put_f64(*z);
                    }
                }
            }
        }
        w.put_usize(self.records.len());
        for rec in &self.records {
            put_record(&mut w, rec);
        }
        w.put_usize(self.nodes.len());
        for blob in &self.nodes {
            w.put_bytes(blob);
        }
        if let Some(roster) = &self.roster {
            w.put_u8(SNAP_TAG_ROSTER);
            w.put_usize(roster.len());
            for &id in roster {
                w.put_u32(id);
            }
        }
        let body = w.finish();
        let mut out = Vec::with_capacity(11 + body.len());
        out.push(CKPT_MAGIC);
        out.push(CKPT_VERSION);
        out.push(KIND_SNAPSHOT);
        out.extend_from_slice(&(body.len() as u32).to_le_bytes());
        out.extend_from_slice(&body);
        out.extend_from_slice(&crc32(&body).to_le_bytes());
        out
    }

    fn decode_body(body: &[u8]) -> Result<Snapshot, String> {
        let mut r = ByteReader::new(body);
        let topology = r.get_str()?.to_string();
        let n = r.get_usize()?;
        let round = r.get_usize()?;
        let ledger = CommLedger {
            messages: r.get_u64()?,
            bytes: r.get_u64()?,
            sim_seconds: r.get_f64()?,
            rounds: r.get_u64()?,
            bytes_on_wire: r.get_u64()?,
        };
        let clock = r.get_f64()?;
        let rng = match r.get_u8()? {
            0 => None,
            1 => {
                let mut s = [0u64; 4];
                for word in &mut s {
                    *word = r.get_u64()?;
                }
                let spare = match r.get_u8()? {
                    0 => None,
                    1 => Some(r.get_f64()?),
                    other => {
                        return Err(format!("bad rng spare flag {other}"))
                    }
                };
                Some((s, spare))
            }
            other => return Err(format!("bad rng presence flag {other}")),
        };
        let n_records = r.get_usize()?;
        let mut records = Vec::with_capacity(n_records.min(1 << 20));
        for _ in 0..n_records {
            records.push(get_record(&mut r)?);
        }
        let n_nodes = r.get_usize()?;
        if n_nodes != n {
            return Err(format!(
                "snapshot stores {n_nodes} node states for n = {n}"
            ));
        }
        let mut nodes = Vec::with_capacity(n_nodes.min(1 << 20));
        for _ in 0..n_nodes {
            nodes.push(r.get_bytes()?.to_vec());
        }
        // Optional tagged tail sections (absent in pre-elastic files).
        let mut roster = None;
        while r.remaining() > 0 {
            match r.get_u8()? {
                SNAP_TAG_ROSTER => {
                    let m = r.get_usize()?;
                    let mut ids = Vec::with_capacity(m.min(1 << 20));
                    for _ in 0..m {
                        ids.push(r.get_u32()?);
                    }
                    roster = Some(ids);
                }
                t => {
                    return Err(format!(
                        "unknown snapshot tail section tag {t}"
                    ))
                }
            }
        }
        r.expect_end()?;
        Ok(Snapshot {
            topology,
            n,
            round,
            nodes,
            ledger,
            records,
            clock,
            rng,
            roster,
        })
    }

    /// Decode complete file bytes, with every corruption mode a typed
    /// error.
    pub fn from_file_bytes(buf: &[u8]) -> Result<Snapshot, CkptError> {
        if buf.is_empty() {
            return Err(CkptError::Truncated { what: "header" });
        }
        if buf[0] != CKPT_MAGIC {
            return Err(CkptError::BadMagic(buf[0]));
        }
        if buf.len() < 2 {
            return Err(CkptError::Truncated { what: "header" });
        }
        if buf[1] != CKPT_VERSION {
            return Err(CkptError::VersionMismatch { found: buf[1] });
        }
        if buf.len() < 7 {
            return Err(CkptError::Truncated { what: "header" });
        }
        if buf[2] != KIND_SNAPSHOT {
            return Err(CkptError::BadKind(buf[2]));
        }
        let len =
            u32::from_le_bytes([buf[3], buf[4], buf[5], buf[6]]) as usize;
        let total = 7usize
            .checked_add(len)
            .and_then(|x| x.checked_add(4))
            .ok_or(CkptError::Truncated { what: "length field" })?;
        if buf.len() < total {
            return Err(CkptError::Truncated { what: "body" });
        }
        if buf.len() > total {
            return Err(CkptError::Malformed(format!(
                "{} trailing bytes after the checksum",
                buf.len() - total
            )));
        }
        let body = &buf[7..7 + len];
        let stored = u32::from_le_bytes([
            buf[7 + len],
            buf[8 + len],
            buf[9 + len],
            buf[10 + len],
        ]);
        if crc32(body) != stored {
            return Err(CkptError::ChecksumMismatch);
        }
        Snapshot::decode_body(body).map_err(CkptError::Malformed)
    }

    /// Load and fully validate a snapshot file.
    pub fn load(path: &Path) -> Result<Snapshot, CkptError> {
        let buf = std::fs::read(path).map_err(|e| {
            CkptError::Io(format!("read {}: {e}", path.display()))
        })?;
        Snapshot::from_file_bytes(&buf)
    }

    /// Check a loaded snapshot against the run it is asked to continue.
    /// `rounds` is the total round count of the resumed run.
    pub fn validate(
        &self,
        n: usize,
        topology: &str,
        rounds: usize,
    ) -> Result<(), String> {
        if self.n != n {
            return Err(format!(
                "snapshot is for n = {} nodes, run has n = {n}",
                self.n
            ));
        }
        if self.topology != topology {
            return Err(format!(
                "snapshot is for topology {:?}, run uses {topology:?}",
                self.topology
            ));
        }
        if self.round > rounds {
            return Err(format!(
                "snapshot is at round {} but the run only has {rounds} \
                 rounds",
                self.round
            ));
        }
        if self.nodes.len() != self.n {
            return Err(format!(
                "snapshot stores {} node states for n = {}",
                self.nodes.len(),
                self.n
            ));
        }
        if let Some(roster) = &self.roster {
            if roster.is_empty() {
                return Err("snapshot roster is empty".into());
            }
            if roster.windows(2).any(|w| w[1] <= w[0])
                || roster.last().map(|&id| id as usize >= self.n)
                    == Some(true)
            {
                return Err(format!(
                    "snapshot roster is not a strictly ascending id set \
                     within 0..{}",
                    self.n
                ));
            }
        }
        Ok(())
    }
}

/// When and where to write snapshots: every `every_n_rounds` completed
/// rounds, into `dir`, keeping the `keep_last` newest files.
#[derive(Debug, Clone)]
pub struct CheckpointPolicy {
    /// Snapshot after every this many completed rounds (0 disables).
    pub every_n_rounds: usize,
    pub dir: PathBuf,
    /// How many snapshot files to retain (0 = keep everything).
    pub keep_last: usize,
    /// One extra forced snapshot after exactly this many completed
    /// rounds, regardless of cadence — how the elastic driver pins a
    /// segment-end boundary without disturbing the user's
    /// `--checkpoint-every` rhythm. `None` for plain runs.
    pub force_at: Option<usize>,
}

impl CheckpointPolicy {
    /// Is a snapshot due after round `r` completes? (Round indices are
    /// 0-based: `due(r)` ⇔ `r + 1` is a multiple of the cadence, or
    /// `r + 1` is the forced boundary.)
    pub fn due(&self, r: usize) -> bool {
        (self.every_n_rounds > 0 && (r + 1) % self.every_n_rounds == 0)
            || self.force_at == Some(r + 1)
    }

    /// Canonical file path for a snapshot taken after `round` completed
    /// rounds — zero-padded so lexicographic order is round order.
    pub fn path_for(&self, round: usize) -> PathBuf {
        self.dir.join(format!("ckpt-{round:08}.bgc"))
    }

    /// Write a snapshot atomically (temp file + rename) and rotate old
    /// files down to `keep_last`.
    pub fn save(&self, snap: &Snapshot) -> Result<PathBuf, String> {
        std::fs::create_dir_all(&self.dir).map_err(|e| {
            format!("create checkpoint dir {}: {e}", self.dir.display())
        })?;
        let path = self.path_for(snap.round);
        let tmp = self.dir.join(format!(".ckpt-{:08}.tmp", snap.round));
        std::fs::write(&tmp, snap.to_file_bytes())
            .map_err(|e| format!("write {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, &path).map_err(|e| {
            format!("rename {} -> {}: {e}", tmp.display(), path.display())
        })?;
        self.rotate()?;
        Ok(path)
    }

    fn rotate(&self) -> Result<(), String> {
        if self.keep_last == 0 {
            return Ok(());
        }
        let mut snaps: Vec<PathBuf> = std::fs::read_dir(&self.dir)
            .map_err(|e| {
                format!("list checkpoint dir {}: {e}", self.dir.display())
            })?
            .filter_map(|entry| entry.ok().map(|e| e.path()))
            .filter(|p| {
                p.file_name()
                    .and_then(|f| f.to_str())
                    .map(|f| f.starts_with("ckpt-") && f.ends_with(".bgc"))
                    .unwrap_or(false)
            })
            .collect();
        // Zero-padded round numbers: name order is round order.
        snaps.sort();
        while snaps.len() > self.keep_last {
            let old = snaps.remove(0);
            std::fs::remove_file(&old).map_err(|e| {
                format!("rotate checkpoint {}: {e}", old.display())
            })?;
        }
        Ok(())
    }
}

/// The checkpoint/resume knobs of one run: an optional write policy and
/// an optional snapshot to resume from. The all-`None` default is a
/// plain run; every executor accepts that for free.
#[derive(Debug, Clone, Default)]
pub struct CkptConfig {
    pub policy: Option<CheckpointPolicy>,
    pub resume: Option<PathBuf>,
    /// Live roster the executor should stamp into every snapshot it
    /// writes (and expect back on resume). `None` for full-roster runs;
    /// set by the elastic driver per segment.
    pub roster: Option<Vec<u32>>,
}

impl CkptConfig {
    /// Does this config ask the executor to do anything at all?
    pub fn is_active(&self) -> bool {
        self.policy.is_some() || self.resume.is_some()
    }

    /// Parse the CLI surface shared by `train`, `simnet` and `repro`:
    /// `--checkpoint-every N` (0 = off), `--checkpoint-dir PATH`
    /// (default `checkpoints`), `--checkpoint-keep K` (default 3) and
    /// `--resume <ckpt file>`.
    pub fn from_args(
        args: &crate::util::cli::Args,
    ) -> Result<CkptConfig, String> {
        let every = args.usize_or("checkpoint-every", 0)?;
        let keep = args.usize_or("checkpoint-keep", 3)?;
        let dir = args.str_or("checkpoint-dir", "checkpoints");
        let policy = (every > 0).then(|| CheckpointPolicy {
            every_n_rounds: every,
            dir: PathBuf::from(dir),
            keep_last: keep,
            force_at: None,
        });
        let resume = args.get("resume").map(PathBuf::from);
        Ok(CkptConfig { policy, resume, roster: None })
    }

    /// Scope this config to one run of a multi-run sweep: the checkpoint
    /// dir (and a directory-valued `resume`) gain a sanitized `label`
    /// subdirectory, so concurrent runs in one sweep never rotate each
    /// other's `ckpt-NNNNNNNN.bgc` files. A file-valued `resume` is left
    /// alone (it already names one specific snapshot). Inactive configs
    /// scope to themselves — zero cost on the default path.
    pub fn scoped(&self, label: &str) -> CkptConfig {
        if !self.is_active() {
            return self.clone();
        }
        let sub: String = label
            .chars()
            .map(|c| {
                if c.is_ascii_alphanumeric() || "._-".contains(c) {
                    c
                } else {
                    '_'
                }
            })
            .collect();
        CkptConfig {
            policy: self.policy.as_ref().map(|p| CheckpointPolicy {
                every_n_rounds: p.every_n_rounds,
                dir: p.dir.join(&sub),
                keep_last: p.keep_last,
                force_at: p.force_at,
            }),
            resume: self.resume.as_ref().map(|r| {
                if r.is_dir() {
                    r.join(&sub)
                } else {
                    r.clone()
                }
            }),
            roster: self.roster.clone(),
        }
    }

    /// Load and validate the snapshot named by `--resume`, if any.
    ///
    /// A *file* path must exist and parse — resuming from a named
    /// snapshot that is gone is an error. A *directory* path is the
    /// lenient crash-recovery form: the newest `ckpt-*.bgc` inside is
    /// loaded, and an empty or missing directory simply starts fresh
    /// (that is what "resume whatever progress exists" means on the
    /// first attempt).
    pub fn load_resume(
        &self,
        n: usize,
        topology: &str,
        rounds: usize,
    ) -> Result<Option<Snapshot>, String> {
        let path = match &self.resume {
            None => return Ok(None),
            Some(path) => path,
        };
        let file = if path.is_dir() {
            match newest_snapshot_in(path)? {
                Some(f) => f,
                None => return Ok(None),
            }
        } else if path.exists() {
            path.clone()
        } else if self.resume_dir_like(path) {
            return Ok(None);
        } else {
            return Err(format!(
                "resume checkpoint {} does not exist",
                path.display()
            ));
        };
        let snap = Snapshot::load(&file).map_err(String::from)?;
        snap.validate(n, topology, rounds)?;
        if let (Some(want), Some(have)) = (&self.roster, &snap.roster) {
            if want != have {
                return Err(format!(
                    "resume snapshot carries roster {have:?}, run expects \
                     {want:?}"
                ));
            }
        }
        Ok(Some(snap))
    }

    /// Does a missing resume path look like a directory request (no
    /// `.bgc` extension)? Those start fresh instead of erroring, so
    /// `--resume <dir>` works on the very first attempt of a run.
    fn resume_dir_like(&self, path: &Path) -> bool {
        path.extension().map(|e| e != "bgc").unwrap_or(true)
    }
}

/// The lexicographically last `ckpt-*.bgc` in `dir` — zero-padded round
/// numbers make that the newest snapshot.
fn newest_snapshot_in(dir: &Path) -> Result<Option<PathBuf>, String> {
    let entries = std::fs::read_dir(dir)
        .map_err(|e| format!("list resume dir {}: {e}", dir.display()))?;
    Ok(entries
        .filter_map(|entry| entry.ok().map(|e| e.path()))
        .filter(|p| {
            p.file_name()
                .and_then(|f| f.to_str())
                .map(|f| f.starts_with("ckpt-") && f.ends_with(".bgc"))
                .unwrap_or(false)
        })
        .max())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_snapshot() -> Snapshot {
        let ledger = CommLedger {
            messages: 42,
            bytes: 4200,
            sim_seconds: 0.125,
            rounds: 6,
            bytes_on_wire: 999,
        };
        Snapshot {
            topology: "Base-4 Graph".into(),
            n: 3,
            round: 6,
            nodes: vec![vec![1, 2, 3], vec![], vec![255; 9]],
            ledger,
            records: vec![
                RoundRecord {
                    round: 5,
                    train_loss: 0.5,
                    consensus_error: f64::NAN,
                    test_loss: f64::NAN,
                    test_acc: f64::NAN,
                    cum_messages: 42,
                    cum_bytes: 4200,
                    cum_wire_bytes: 999,
                    sim_seconds: 0.125,
                    wall_seconds: 0.001,
                    combine_ns: 7, // not persisted: must read back as 0
                },
            ],
            clock: 1.5,
            rng: Some(([1, 2, 3, 4], Some(-0.25))),
            roster: None,
        }
    }

    #[test]
    fn snapshot_round_trips_bit_exactly() {
        let s = sample_snapshot();
        let bytes = s.to_file_bytes();
        let back = Snapshot::from_file_bytes(&bytes).unwrap();
        assert_eq!(back.topology, s.topology);
        assert_eq!(back.n, s.n);
        assert_eq!(back.round, s.round);
        assert_eq!(back.nodes, s.nodes);
        assert_eq!(back.ledger.messages, s.ledger.messages);
        assert_eq!(back.ledger.bytes, s.ledger.bytes);
        assert_eq!(
            back.ledger.sim_seconds.to_bits(),
            s.ledger.sim_seconds.to_bits()
        );
        assert_eq!(back.ledger.rounds, s.ledger.rounds);
        assert_eq!(back.ledger.bytes_on_wire, s.ledger.bytes_on_wire);
        assert_eq!(back.clock.to_bits(), s.clock.to_bits());
        assert_eq!(back.rng, s.rng);
        assert_eq!(back.records.len(), 1);
        let (a, b) = (&back.records[0], &s.records[0]);
        assert_eq!(a.round, b.round);
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
        assert!(a.consensus_error.is_nan());
        assert_eq!(a.cum_wire_bytes, b.cum_wire_bytes);
        assert_eq!(a.combine_ns, 0, "kernel timing is not persisted");
        assert!(back.validate(3, "Base-4 Graph", 10).is_ok());
        assert!(back.validate(4, "Base-4 Graph", 10).is_err());
        assert!(back.validate(3, "Ring", 10).is_err());
        assert!(back.validate(3, "Base-4 Graph", 5).is_err());
    }

    #[test]
    fn policy_due_and_paths() {
        let p = CheckpointPolicy {
            every_n_rounds: 3,
            dir: PathBuf::from("/tmp/x"),
            keep_last: 2,
            force_at: None,
        };
        assert!(!p.due(0));
        assert!(!p.due(1));
        assert!(p.due(2)); // 3 rounds completed
        assert!(p.due(5));
        assert_eq!(
            p.path_for(12),
            PathBuf::from("/tmp/x/ckpt-00000012.bgc")
        );
        let off = CheckpointPolicy { every_n_rounds: 0, ..p.clone() };
        assert!(!off.due(0) && !off.due(99));
        // force_at adds one boundary on top of the cadence (and works
        // with the cadence off entirely).
        let forced = CheckpointPolicy { force_at: Some(5), ..p };
        assert!(forced.due(2) && forced.due(4) && forced.due(5));
        assert!(!forced.due(3));
        let only = CheckpointPolicy {
            every_n_rounds: 0,
            dir: PathBuf::from("/tmp/x"),
            keep_last: 0,
            force_at: Some(7),
        };
        assert!(only.due(6));
        assert!(!only.due(5) && !only.due(7));
    }

    #[test]
    fn roster_tail_round_trips_and_stays_legacy_compatible() {
        // None-roster snapshots are byte-identical to the pre-elastic
        // format (no tail section at all).
        let plain = sample_snapshot();
        let bytes = plain.to_file_bytes();
        let mut tailed = plain.clone();
        tailed.roster = Some(vec![0, 2]);
        let tailed_bytes = tailed.to_file_bytes();
        assert!(tailed_bytes.len() > bytes.len());
        let back = Snapshot::from_file_bytes(&tailed_bytes).unwrap();
        assert_eq!(back.roster, Some(vec![0, 2]));
        assert_eq!(
            Snapshot::from_file_bytes(&bytes).unwrap().roster,
            None
        );
        // validate() rejects malformed rosters.
        assert!(back.validate(3, "Base-4 Graph", 10).is_ok());
        let mut bad = plain.clone();
        bad.roster = Some(vec![2, 0]);
        assert!(bad.validate(3, "Base-4 Graph", 10).is_err());
        bad.roster = Some(vec![0, 7]);
        assert!(bad.validate(3, "Base-4 Graph", 10).is_err());
        bad.roster = Some(Vec::new());
        assert!(bad.validate(3, "Base-4 Graph", 10).is_err());
        // An unknown tail tag is a clean Malformed error.
        let mut corrupt = plain.to_file_bytes();
        // Rebuild with a bogus tail: append tag 9 to the body by hand.
        let len = u32::from_le_bytes([
            corrupt[3], corrupt[4], corrupt[5], corrupt[6],
        ]) as usize;
        let mut body = corrupt[7..7 + len].to_vec();
        body.push(9);
        corrupt = Vec::new();
        corrupt.push(CKPT_MAGIC);
        corrupt.push(CKPT_VERSION);
        corrupt.push(KIND_SNAPSHOT);
        corrupt.extend_from_slice(&(body.len() as u32).to_le_bytes());
        corrupt.extend_from_slice(&body);
        corrupt.extend_from_slice(&crc32(&body).to_le_bytes());
        match Snapshot::from_file_bytes(&corrupt) {
            Err(CkptError::Malformed(e)) => {
                assert!(e.contains("tail section"), "{e}")
            }
            other => panic!("expected Malformed, got {other:?}"),
        }
    }

    #[test]
    fn config_parses_cli_flags() {
        let raw: Vec<String> = [
            "--checkpoint-every",
            "5",
            "--checkpoint-dir",
            "/tmp/ck",
            "--checkpoint-keep",
            "7",
            "--resume",
            "/tmp/ck/ckpt-00000005.bgc",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let args = crate::util::cli::Args::parse(&raw, &[]).unwrap();
        let c = CkptConfig::from_args(&args).unwrap();
        assert!(c.is_active());
        let p = c.policy.unwrap();
        assert_eq!(p.every_n_rounds, 5);
        assert_eq!(p.dir, PathBuf::from("/tmp/ck"));
        assert_eq!(p.keep_last, 7);
        assert_eq!(
            c.resume,
            Some(PathBuf::from("/tmp/ck/ckpt-00000005.bgc"))
        );
        let none = CkptConfig::from_args(
            &crate::util::cli::Args::parse(&[], &[]).unwrap(),
        )
        .unwrap();
        assert!(!none.is_active());
    }
}
