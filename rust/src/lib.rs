//! # BaseGraph: communication-efficient topologies for decentralized learning
//!
//! A Rust + JAX + Pallas reproduction of *"Beyond Exponential Graph:
//! Communication-Efficient Topologies for Decentralized Learning via
//! Finite-time Convergence"* (Takezawa, Sato, Bao, Niwa, Yamada — NeurIPS 2023).
//!
//! The crate is organized in three layers:
//!
//! * **Layer 3 (this crate)** — the decentralized-training coordinator:
//!   time-varying topology construction (the paper's contribution) as
//!   sparse per-node [`GossipPlan`]s, the O(edges·d) gossip engine, the
//!   [`exec`] execution layer (one [`Workload`] contract over the
//!   analytic loop, the [`simnet`] discrete-event network simulator —
//!   stragglers, lossy and heterogeneous links, asynchronous gossip — a
//!   thread-parallel backend with measured wall-clock, and a
//!   process-parallel backend: one OS worker process per node shard,
//!   gossip over real sockets, with exact measured bytes-on-the-wire),
//!   decentralized optimizers (DSGD, DSGDm, QG-DSGDm, D²), data
//!   partitioning (Dirichlet heterogeneity), metrics and the CLI. Dense
//!   [`MixingMatrix`] views are derived on demand (`plan.to_dense()`) for
//!   spectral analysis and verification only — no per-round path holds an
//!   n×n matrix, which is what lets consensus and training run at n in the
//!   thousands.
//! * **Layer 2 (`python/compile/model.py`)** — JAX forward/backward graphs of
//!   the models being trained, AOT-lowered to HLO text at build time.
//! * **Layer 1 (`python/compile/kernels/`)** — Pallas kernels for the compute
//!   hot spots (blocked matmul, gossip mixing), lowered into the same HLO.
//!
//! Python never runs on the training path: the Rust binary loads the
//! artifacts with the PJRT C API (`xla` crate) and drives everything.
//!
//! The architecture book — layered tour, execution-backend walkthroughs
//! (including "how to add a backend", worked on
//! [`ProcessExecutor`](exec::ProcessExecutor)), determinism/equivalence
//! rules and the full CLI reference — lives in `docs/ARCHITECTURE.md`
//! at the repository root.

pub mod ckpt;
pub mod codec;
pub mod comm;
pub mod consensus;
pub mod data;
pub mod exec;
pub mod kernels;
pub mod metrics;
pub mod optim;
pub mod repro;
pub mod runtime;
pub mod simnet;
pub mod telemetry;
pub mod train;
pub mod topology;
pub mod util;

pub use codec::Codec;
pub use exec::{ExecTrace, Executor, ExecutorKind, Workload};
pub use simnet::SimConfig;
pub use topology::{GossipPlan, GraphSequence, MixingMatrix, TopologyKind};
pub use util::rng::Rng;
