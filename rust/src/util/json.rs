//! Minimal JSON parser + writer (the build is offline; replaces serde_json).
//!
//! Supports the full JSON grammar minus exotic number formats; used for the
//! artifact manifest (`artifacts/manifest.json`) and metric dumps. Strings
//! support the standard escapes including `\uXXXX` (BMP only — enough for
//! everything this repo writes).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept sorted (BTreeMap) so output is stable.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// Object field access; `None` when not an object or key missing.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Convenience constructors for the writer side.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, ParseError> {
        Err(ParseError { offset: self.pos, message: msg.to_string() })
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(&format!("expected '{}'", b as char))
        }
    }

    fn literal(&mut self, lit: &str, value: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(value)
        } else {
            self.err(&format!("expected '{lit}'"))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => self.err("unexpected character"),
            None => self.err("unexpected end of input"),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self
                                .bump()
                                .ok_or_else(|| ParseError {
                                    offset: self.pos,
                                    message: "truncated \\u escape".into(),
                                })?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| {
                                    ParseError {
                                        offset: self.pos,
                                        message: "bad hex in \\u".into(),
                                    }
                                })?;
                        }
                        out.push(
                            char::from_u32(code).unwrap_or('\u{FFFD}'),
                        );
                    }
                    _ => return self.err("bad escape"),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return self.err("invalid utf-8"),
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    match std::str::from_utf8(&self.bytes[start..self.pos]) {
                        Ok(s) => out.push_str(s),
                        Err(_) => return self.err("invalid utf-8"),
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| ParseError {
                offset: start,
                message: format!("bad number {s:?}"),
            })
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }
}

/// Parse a JSON document (trailing whitespace allowed, trailing junk not).
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing characters");
    }
    Ok(v)
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_into(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 1e15 {
                out.push_str(&format!("{}", *x as i64));
            } else {
                out.push_str(&format!("{x}"));
            }
        }
        Json::Str(s) => escape_into(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(item, out);
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape_into(k, out);
                out.push(':');
                write_into(val, out);
            }
            out.push('}');
        }
    }
}

/// Serialize compactly.
pub fn write(v: &Json) -> String {
    let mut out = String::new();
    write_into(v, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_escapes() {
        assert_eq!(
            parse(r#""a\nb\t\"c\" A""#).unwrap(),
            Json::Str("a\nb\t\"c\" A".into())
        );
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "d"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("d"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64(), Some(2.0));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("1 x").is_err());
        assert!(parse("{").is_err());
        assert!(parse(r#"{"a":}"#).is_err());
        assert!(parse("[1,]").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"mix":[{"d":128,"m":3}],"name":"x \"q\"","v":[1.5,true,null]}"#;
        let v = parse(src).unwrap();
        let out = write(&v);
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn integers_written_without_fraction() {
        assert_eq!(write(&Json::Num(42.0)), "42");
        assert_eq!(write(&Json::Num(0.5)), "0.5");
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo ✓\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ✓"));
        assert_eq!(parse(&write(&v)).unwrap(), v);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "version": 1,
          "models": [
            {"name": "mlp", "variant": "pallas", "d_params": 26122,
             "train": {"hlo": "mlp_pallas_train.hlo.txt", "batch": 32,
                        "x_shape": [32, 64], "x_dtype": "f32",
                        "y_shape": [32], "y_dtype": "i32"}}
          ],
          "mix": []
        }"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("version").unwrap().as_usize(), Some(1));
        let m = &v.get("models").unwrap().as_arr().unwrap()[0];
        assert_eq!(m.get("d_params").unwrap().as_usize(), Some(26122));
        assert_eq!(
            m.get("train").unwrap().get("x_shape").unwrap().as_arr().unwrap()
                [1]
            .as_usize(),
            Some(64)
        );
    }
}
