//! Seeded randomized property-test runner (offline build; replaces proptest).
//!
//! `check(name, cases, |rng| ...)` runs the closure against `cases`
//! independently-seeded RNGs; on failure it reports the failing case seed so
//! the case reproduces with `check_one(seed, ...)`. Properties return
//! `Result<(), String>` so failures carry a message instead of panicking
//! deep inside the property body.

use crate::util::rng::Rng;

/// Default number of cases; override with BASEGRAPH_PROP_CASES.
pub fn default_cases() -> usize {
    std::env::var("BASEGRAPH_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` for `cases` deterministic seeds; panic with the failing seed
/// on the first failure.
pub fn check<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = derive_seed(name, case as u64);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property {name:?} failed at case {case} (seed \
                 {seed:#018x}): {msg}\nreproduce with \
                 util::prop::check_one({seed:#018x}, ...)"
            );
        }
    }
}

/// Re-run a single failing case by seed.
pub fn check_one<F>(seed: u64, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property failed (seed {seed:#018x}): {msg}");
    }
}

fn derive_seed(name: &str, case: u64) -> u64 {
    // FNV-1a over the name, mixed with the case index.
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h ^ case.wrapping_mul(0x9E3779B97F4A7C15)
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        let counter = std::cell::Cell::new(0usize);
        check("always-true", 32, |_rng| {
            counter.set(counter.get() + 1);
            Ok(())
        });
        count += counter.get();
        assert_eq!(count, 32);
    }

    #[test]
    #[should_panic(expected = "property \"always-false\" failed")]
    fn failing_property_panics_with_seed() {
        check("always-false", 8, |_rng| Err("nope".into()));
    }

    #[test]
    fn seeds_are_deterministic_per_name_and_case() {
        assert_eq!(derive_seed("x", 3), derive_seed("x", 3));
        assert_ne!(derive_seed("x", 3), derive_seed("x", 4));
        assert_ne!(derive_seed("x", 3), derive_seed("y", 3));
    }

    #[test]
    fn prop_assert_macro_returns_error() {
        let f = |rng: &mut crate::util::rng::Rng| -> Result<(), String> {
            let v = rng.below(10);
            prop_assert!(v < 10, "v={v} out of range");
            Ok(())
        };
        check("macro-smoke", 16, f);
    }
}
