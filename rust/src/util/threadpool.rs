//! A small persistent-worker thread pool (offline build; replaces
//! rayon/tokio for the coordinator's fan-out sections).
//!
//! The training engine's per-round pattern is "run the same closure for
//! each of n nodes, then join", three times per round. Workers are spawned
//! once and kept alive — per-call `std::thread::spawn` costs ~50µs/thread,
//! which dominated the round time for small models (EXPERIMENTS.md §Perf).
//! Work is pulled from an atomic counter so uneven per-item cost balances.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Latch {
    remaining: Mutex<usize>,
    cv: Condvar,
}

impl Latch {
    fn new(count: usize) -> Arc<Self> {
        Arc::new(Latch { remaining: Mutex::new(count), cv: Condvar::new() })
    }
    fn count_down(&self) {
        let mut r = self.remaining.lock().unwrap();
        *r -= 1;
        if *r == 0 {
            self.cv.notify_all();
        }
    }
    fn wait(&self) {
        let mut r = self.remaining.lock().unwrap();
        while *r != 0 {
            r = self.cv.wait(r).unwrap();
        }
    }
}

/// Fixed-width data-parallel executor with persistent workers.
pub struct ThreadPool {
    sender: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Pool sized to the machine (logical cores, capped at `cap`).
    pub fn with_default_size(cap: usize) -> Self {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(cap)
            .max(1);
        Self::new(n)
    }

    pub fn new(size: usize) -> Self {
        assert!(size > 0);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|_| {
                let rx = rx.clone();
                std::thread::spawn(move || loop {
                    let job = rx.lock().unwrap().recv();
                    match job {
                        Ok(job) => job(),
                        Err(_) => return, // sender dropped: shut down
                    }
                })
            })
            .collect();
        ThreadPool { sender: Some(tx), workers, size }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Run `f(i, &mut items[i])` for every element, in parallel, then
    /// join. Thin wrapper over [`ThreadPool::for_each_mut2`] with a
    /// zero-sized second slice (free — `Vec<()>` never allocates and the
    /// pointer arithmetic on it is a no-op), so the unsafe dispatch
    /// machinery exists exactly once.
    pub fn for_each_mut<T, F>(&self, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        let mut units = vec![(); items.len()];
        self.for_each_mut2(items, &mut units, |i, item, _| f(i, item));
    }

    /// Run `f(i, &mut a[i], &mut b[i])` for every index, in parallel, then
    /// join — the core dispatch ([`ThreadPool::for_each_mut`] is a
    /// zero-cost wrapper over this). The lock-step engines use the
    /// two-slice form to pair each node with its persistent
    /// combine-scratch buffer without zipping into a fresh Vec per round.
    ///
    /// SAFETY argument for the lifetime erasure below: every index in
    /// 0..n is claimed by exactly one worker via the atomic counter, the
    /// two slices are checked equal-length and their elements are
    /// disjoint, and the latch blocks this frame until every job has
    /// finished, so the borrows of `a`, `b` and `f` cannot escape.
    pub fn for_each_mut2<T, U, F>(&self, a: &mut [T], b: &mut [U], f: F)
    where
        T: Send,
        U: Send,
        F: Fn(usize, &mut T, &mut U) + Sync,
    {
        let n = a.len();
        assert_eq!(n, b.len(), "for_each_mut2: slice lengths differ");
        if n == 0 {
            return;
        }
        let workers = self.size.min(n);
        if workers == 1 {
            for (i, (x, y)) in a.iter_mut().zip(b.iter_mut()).enumerate() {
                f(i, x, y);
            }
            return;
        }
        let next = Arc::new(AtomicUsize::new(0));
        let latch = Latch::new(workers);
        let base_a = a.as_mut_ptr() as usize;
        let base_b = b.as_mut_ptr() as usize;
        let f_addr = &f as *const F as usize;
        let sender = self.sender.as_ref().expect("pool alive");
        for _ in 0..workers {
            let next = next.clone();
            let latch = latch.clone();
            let job: Job = Box::new(move || {
                // Reconstruct the erased references; valid until the latch
                // releases the caller (see SAFETY above).
                let f = unsafe { &*(f_addr as *const F) };
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let x = unsafe { &mut *(base_a as *mut T).add(i) };
                    let y = unsafe { &mut *(base_b as *mut U).add(i) };
                    f(i, x, y);
                }
                latch.count_down();
            });
            sender.send(job).expect("workers alive");
        }
        latch.wait();
    }

    /// Map `f(i)` over `0..n` in parallel, collecting results in order.
    pub fn map<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        self.for_each_mut(&mut out, |i, slot| *slot = Some(f(i)));
        out.into_iter().map(|x| x.unwrap()).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.sender.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_every_job_exactly_once() {
        let pool = ThreadPool::new(4);
        let mut items = vec![0u64; 1000];
        pool.for_each_mut(&mut items, |i, x| *x = i as u64 + 1);
        for (i, x) in items.iter().enumerate() {
            assert_eq!(*x, i as u64 + 1);
        }
    }

    #[test]
    fn for_each_mut2_pairs_slices_exactly_once() {
        let pool = ThreadPool::new(4);
        let mut a = vec![0u64; 513];
        let mut b: Vec<u64> = (0..513).collect();
        pool.for_each_mut2(&mut a, &mut b, |i, x, y| {
            *x = i as u64 + *y;
            *y += 1;
        });
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(*x, 2 * i as u64);
            assert_eq!(*y, i as u64 + 1);
        }
        // Single-worker and empty paths.
        let solo = ThreadPool::new(1);
        let mut a = vec![0u8; 3];
        let mut b = vec![0u8; 3];
        solo.for_each_mut2(&mut a, &mut b, |i, x, _| *x = i as u8);
        assert_eq!(a, vec![0, 1, 2]);
        let mut e1: Vec<u8> = vec![];
        let mut e2: Vec<u8> = vec![];
        pool.for_each_mut2(&mut e1, &mut e2, |_, _, _| {});
    }

    #[test]
    #[should_panic(expected = "slice lengths differ")]
    fn for_each_mut2_rejects_mismatched_lengths() {
        let pool = ThreadPool::new(2);
        let mut a = vec![0u8; 4];
        let mut b = vec![0u8; 5];
        pool.for_each_mut2(&mut a, &mut b, |_, _, _| {});
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(8);
        let out = pool.map(257, |i| i * i);
        assert_eq!(out.len(), 257);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn reusable_across_rounds() {
        let pool = ThreadPool::new(3);
        let counter = AtomicU64::new(0);
        for _ in 0..50 {
            let mut items = vec![(); 17];
            pool.for_each_mut(&mut items, |_, _| {
                counter.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(counter.load(Ordering::Relaxed), 50 * 17);
    }

    #[test]
    fn empty_input_ok() {
        let pool = ThreadPool::new(2);
        let mut items: Vec<u8> = vec![];
        pool.for_each_mut(&mut items, |_, _| {});
    }

    #[test]
    fn single_worker_path() {
        let pool = ThreadPool::new(1);
        let mut items = vec![0usize; 64];
        pool.for_each_mut(&mut items, |i, x| *x = i);
        assert_eq!(items[63], 63);
    }

    #[test]
    fn parallelism_actually_happens() {
        // With 4 workers, 4 jobs each sleeping 50ms should take ~50ms,
        // not 200ms.
        let pool = ThreadPool::new(4);
        let start = std::time::Instant::now();
        let mut items = vec![(); 4];
        pool.for_each_mut(&mut items, |_, _| {
            std::thread::sleep(std::time::Duration::from_millis(50))
        });
        assert!(start.elapsed() < std::time::Duration::from_millis(160));
    }

    #[test]
    fn uneven_work_balances() {
        let pool = ThreadPool::new(4);
        let mut items: Vec<u64> = (0..64).collect();
        pool.for_each_mut(&mut items, |i, x| {
            if i % 16 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            *x += 1;
        });
        assert_eq!(items.iter().sum::<u64>(), (0..64u64).sum::<u64>() + 64);
    }

    #[test]
    fn borrows_outer_state_safely() {
        // Closures may capture references to caller-frame data.
        let pool = ThreadPool::new(4);
        let weights: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let mut out = vec![0.0f64; 100];
        pool.for_each_mut(&mut out, |i, o| *o = weights[i] * 2.0);
        assert_eq!(out[99], 198.0);
    }

    #[test]
    fn dispatch_overhead_is_small() {
        // 1000 trivial fan-outs must complete quickly (persistent workers;
        // this was ~50µs/thread with per-call spawn).
        let pool = ThreadPool::new(4);
        let mut items = vec![0u8; 8];
        let t0 = std::time::Instant::now();
        for _ in 0..1000 {
            pool.for_each_mut(&mut items, |_, x| {
                *x = x.wrapping_add(1);
            });
        }
        let per_call = t0.elapsed().as_micros() as f64 / 1000.0;
        assert!(per_call < 500.0, "per-call dispatch {per_call}µs");
    }
}
