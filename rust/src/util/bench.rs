//! Mini benchmark harness (offline build; replaces criterion).
//!
//! `cargo bench` targets in `rust/benches/` use `harness = false` and drive
//! this: warmup, timed iterations until a wall-clock budget, then
//! mean/median/p95 plus throughput. Results are printed as a table and
//! optionally appended as JSON lines for EXPERIMENTS.md bookkeeping.

use std::time::{Duration, Instant};

/// One benchmark's collected statistics (nanoseconds per iteration).
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl Stats {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

/// Benchmark runner with a per-case time budget.
pub struct Bencher {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
    results: Vec<Stats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            min_iters: 10,
            max_iters: 1_000_000,
            results: Vec::new(),
        }
    }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick-mode bencher for CI (BASEGRAPH_BENCH_FAST=1 shrinks budgets).
    pub fn from_env() -> Self {
        let mut b = Self::default();
        if std::env::var("BASEGRAPH_BENCH_FAST").as_deref() == Ok("1") {
            b.warmup = Duration::from_millis(20);
            b.budget = Duration::from_millis(200);
            b.min_iters = 3;
        }
        b
    }

    /// Time `f` repeatedly; returns and records the stats.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> Stats {
        // Warmup.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            f();
        }
        // Timed samples.
        let mut samples_ns: Vec<f64> = Vec::new();
        let t0 = Instant::now();
        while (t0.elapsed() < self.budget || samples_ns.len() < self.min_iters)
            && samples_ns.len() < self.max_iters
        {
            let s = Instant::now();
            f();
            samples_ns.push(s.elapsed().as_nanos() as f64);
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples_ns.len();
        let stats = Stats {
            name: name.to_string(),
            iters: n,
            mean_ns: samples_ns.iter().sum::<f64>() / n as f64,
            median_ns: samples_ns[n / 2],
            p95_ns: samples_ns[(n as f64 * 0.95) as usize % n],
            min_ns: samples_ns[0],
        };
        println!(
            "{:<52} {:>10} iters  mean {:>12}  median {:>12}  p95 {:>12}",
            stats.name,
            stats.iters,
            fmt_ns(stats.mean_ns),
            fmt_ns(stats.median_ns),
            fmt_ns(stats.p95_ns),
        );
        self.results.push(stats.clone());
        stats
    }

    /// All results so far.
    pub fn results(&self) -> &[Stats] {
        &self.results
    }

    /// Append one JSON line per result to `path` (best-effort).
    pub fn dump_jsonl(&self, path: &str) {
        use crate::util::json::Json;
        let mut out = String::new();
        for s in &self.results {
            let j = Json::obj(vec![
                ("name", Json::str(&s.name)),
                ("iters", Json::num(s.iters as f64)),
                ("mean_ns", Json::num(s.mean_ns)),
                ("median_ns", Json::num(s.median_ns)),
                ("p95_ns", Json::num(s.p95_ns)),
            ]);
            out.push_str(&crate::util::json::write(&j));
            out.push('\n');
        }
        if let Some(dir) = std::path::Path::new(path).parent() {
            let _ = std::fs::create_dir_all(dir);
        }
        use std::io::Write;
        if let Ok(mut f) = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
        {
            let _ = f.write_all(out.as_bytes());
        }
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_stats() {
        let mut b = Bencher {
            warmup: Duration::from_millis(1),
            budget: Duration::from_millis(20),
            min_iters: 5,
            max_iters: 10_000,
            results: vec![],
        };
        let s = b.bench("noop-ish", || {
            black_box((0..100).sum::<usize>());
        });
        assert!(s.iters >= 5);
        assert!(s.mean_ns > 0.0);
        assert!(s.min_ns <= s.median_ns && s.median_ns <= s.p95_ns);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn ns_formatting() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.2e9), "3.200 s");
    }
}
