//! Small self-contained substrates (the build is fully offline, so these
//! replace the usual crates.io dependencies: PRNG, JSON, CLI parsing,
//! thread pool, benchmarking and property-test harnesses).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod threadpool;

/// Write a CSV file (creates parent dirs). Rows are plain strings; the
/// caller formats numbers so scientific experiments control precision.
pub fn write_csv(
    path: &str,
    header: &[&str],
    rows: &[Vec<String>],
) -> std::io::Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut out = String::new();
    out.push_str(&header.join(","));
    out.push('\n');
    for row in rows {
        out.push_str(&row.join(","));
        out.push('\n');
    }
    std::fs::write(path, out)
}

#[cfg(test)]
mod tests {
    #[test]
    fn write_csv_roundtrip() {
        let dir = std::env::temp_dir().join("basegraph_csv_test");
        let path = dir.join("t.csv");
        let p = path.to_str().unwrap();
        super::write_csv(
            p,
            &["a", "b"],
            &[vec!["1".into(), "2".into()], vec!["3".into(), "4".into()]],
        )
        .unwrap();
        let text = std::fs::read_to_string(p).unwrap();
        assert_eq!(text, "a,b\n1,2\n3,4\n");
        let _ = std::fs::remove_dir_all(dir);
    }
}
