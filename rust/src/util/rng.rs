//! Deterministic pseudo-random number generation.
//!
//! The build is fully offline (no `rand` crate), so we carry a small,
//! well-understood generator: SplitMix64 for seeding and xoshiro256++ for the
//! main stream, plus the distribution samplers the paper's experiments need
//! (uniform, Gaussian via Box–Muller, Gamma via Marsaglia–Tsang, Dirichlet,
//! permutations and categorical draws).

/// xoshiro256++ PRNG (Blackman & Vigna). Deterministic, seedable, fast.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the last Box–Muller draw.
    gauss_spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded with SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Export the full generator state — the four xoshiro256++ state
    /// words plus the cached Box–Muller spare — for checkpointing. A
    /// generator rebuilt with [`Rng::from_state`] continues the exact
    /// same stream.
    pub fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.gauss_spare)
    }

    /// Rebuild a generator mid-stream from an exported [`Rng::state`].
    pub fn from_state(s: [u64; 4], gauss_spare: Option<f64>) -> Rng {
        Rng { s, gauss_spare }
    }

    /// Derive an independent child stream (e.g. one per node).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mix = self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        Rng::new(mix)
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, n)` (Lemire's method would be faster; modulo
    /// bias is negligible for n << 2^64 and determinism is what we need).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "Rng::range: empty range");
        lo + self.below(hi - lo)
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // Draw u1 in (0,1] to avoid ln(0).
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (2000); boosts shape < 1.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        assert!(shape > 0.0, "gamma shape must be positive");
        if shape < 1.0 {
            // Gamma(a) = Gamma(a+1) * U^(1/a)
            let u: f64 = self.next_f64().max(f64::MIN_POSITIVE);
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.next_f64();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v;
            }
            if u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Symmetric Dirichlet(alpha) over `k` categories.
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let sum: f64 = g.iter().sum();
        if sum <= 0.0 {
            // Extremely small alpha can underflow all gammas; fall back to a
            // one-hot draw, which is the alpha -> 0 limit.
            let mut out = vec![0.0; k];
            out[self.below(k)] = 1.0;
            return out;
        }
        for x in &mut g {
            *x /= sum;
        }
        g
    }

    /// Draw an index from an (unnormalized, non-negative) weight vector.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical: weights sum to zero");
        let mut t = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut p: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            p.swap(i, j);
        }
        p.truncate(k);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..16).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_mean_reasonable() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(11);
        for &shape in &[0.3, 1.0, 2.5, 8.0] {
            let n = 20_000;
            let mean: f64 = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!(
                (mean - shape).abs() < 0.1 * shape.max(1.0),
                "shape={shape} mean={mean}"
            );
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(13);
        for &alpha in &[0.1, 1.0, 10.0] {
            let p = r.dirichlet(alpha, 10);
            assert_eq!(p.len(), 10);
            let s: f64 = p.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn dirichlet_small_alpha_is_peaked() {
        let mut r = Rng::new(17);
        // With alpha = 0.05 the mass should concentrate on few categories.
        let p = r.dirichlet(0.05, 10);
        let max = p.iter().cloned().fold(0.0, f64::max);
        assert!(max > 0.5, "max={max}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(19);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::new(23);
        let ks = r.choose_k(50, 10);
        assert_eq!(ks.len(), 10);
        let mut sorted = ks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 10);
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(29);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!((counts[2] as f64 / 30_000.0 - 0.7).abs() < 0.02);
        assert!((counts[1] as f64 / 30_000.0 - 0.2).abs() < 0.02);
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Rng::new(31);
        let mut a = parent.fork(0);
        let mut b = parent.fork(1);
        let same = (0..16).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
