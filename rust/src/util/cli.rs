//! Tiny CLI argument parser (offline build; replaces clap).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments and
//! subcommands; typed getters with defaults and error messages that name the
//! offending flag.

use std::collections::BTreeMap;

/// Parsed arguments for one (sub)command invocation.
#[derive(Debug, Clone, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse raw arguments. Flags that take no value must be listed in
    /// `bool_flags` so `--verbose foo` treats `foo` as positional.
    pub fn parse(raw: &[String], bool_flags: &[&str]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&body) {
                    out.flags.push(body.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    out.opts.insert(body.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    return Err(format!("option --{body} requires a value"));
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key}: expected integer, got {v:?}")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key}: expected integer, got {v:?}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key}: expected number, got {v:?}")),
        }
    }

    /// Comma-separated list of usizes, e.g. `--ns 21,22,25`.
    pub fn usize_list_or(
        &self,
        key: &str,
        default: &[usize],
    ) -> Result<Vec<usize>, String> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim().parse().map_err(|_| {
                        format!("--{key}: bad integer {s:?} in list")
                    })
                })
                .collect(),
        }
    }

    /// Comma-separated list of strings.
    pub fn str_list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.trim().to_string())
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn raw(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_key_value_both_styles() {
        let a = Args::parse(&raw("--n 25 --k=3 pos1"), &[]).unwrap();
        assert_eq!(a.get("n"), Some("25"));
        assert_eq!(a.get("k"), Some("3"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn bool_flags_do_not_eat_values() {
        let a = Args::parse(&raw("--verbose train --n 5"), &["verbose"])
            .unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["train".to_string()]);
        assert_eq!(a.usize_or("n", 0).unwrap(), 5);
    }

    #[test]
    fn typed_getters_and_defaults() {
        let a = Args::parse(&raw("--lr 0.05"), &[]).unwrap();
        assert_eq!(a.f64_or("lr", 0.1).unwrap(), 0.05);
        assert_eq!(a.f64_or("alpha", 0.1).unwrap(), 0.1);
        assert_eq!(a.usize_or("rounds", 100).unwrap(), 100);
        assert!(a.usize_or("lr", 1).is_err());
    }

    #[test]
    fn lists() {
        let a = Args::parse(&raw("--ns 21,22,25 --topos ring,base"), &[])
            .unwrap();
        assert_eq!(a.usize_list_or("ns", &[]).unwrap(), vec![21, 22, 25]);
        assert_eq!(a.str_list_or("topos", &[]), vec!["ring", "base"]);
        assert_eq!(a.usize_list_or("ks", &[1, 2]).unwrap(), vec![1, 2]);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&raw("--n"), &[]).is_err());
    }
}
