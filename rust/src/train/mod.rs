//! The decentralized training engine: DSGD-family training over a
//! time-varying topology (Eq. 1 of the paper), with parallel local
//! gradients, sparse neighbor-list gossip, communication accounting and
//! periodic evaluation of the node-averaged model.
//!
//! Gossip walks each node's [`GossipPlan`](crate::topology::GossipPlan)
//! neighbor list — O(degree · d) per node per round — so per-round cost
//! scales with the real messages exchanged, not with n².

pub mod node_data;

use crate::comm::{CommLedger, CostModel};
use crate::consensus;
use crate::metrics::{RoundRecord, RunResult};
use crate::optim::OptimizerKind;
use crate::runtime::batch::Batch;
use crate::runtime::provider::GradProvider;
use crate::topology::GraphSequence;
use crate::util::threadpool::ThreadPool;
use node_data::NodeData;

/// Training hyperparameters (paper Sec. H analogue).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub rounds: usize,
    pub lr: f64,
    /// Linear LR warmup rounds (paper: 10 epochs).
    pub warmup: usize,
    /// Cosine decay after warmup (paper: cosine scheduler).
    pub cosine: bool,
    pub optimizer: OptimizerKind,
    /// Evaluate every this many rounds (0 = only at the end).
    pub eval_every: usize,
    /// Worker threads for local gradient computation.
    pub threads: usize,
    pub cost: CostModel,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            rounds: 100,
            lr: 0.1,
            warmup: 10,
            cosine: true,
            optimizer: OptimizerKind::Dsgdm { momentum: 0.9 },
            eval_every: 10,
            threads: 0, // 0 = auto
            cost: CostModel::default(),
        }
    }
}

impl TrainConfig {
    /// LR at round r: linear warmup then (optionally) cosine decay to 0.
    pub fn lr_at(&self, r: usize) -> f64 {
        if self.warmup > 0 && r < self.warmup {
            return self.lr * (r + 1) as f64 / self.warmup as f64;
        }
        if !self.cosine || self.rounds <= self.warmup {
            return self.lr;
        }
        let t = (r - self.warmup) as f64
            / (self.rounds - self.warmup).max(1) as f64;
        self.lr * 0.5 * (1.0 + (std::f64::consts::PI * t).cos())
    }
}

struct NodeState {
    params: Vec<f32>,
    opt: Box<dyn crate::optim::DecentralizedOptimizer>,
    data: Box<dyn NodeData>,
    last_loss: f64,
    pending: Vec<Vec<f32>>,
    error: Option<String>,
}

/// Run decentralized training of `provider` over `seq`.
///
/// `node_data[i]` supplies node i's batches; `eval_batches` are evaluated
/// on the node-averaged model at eval points.
pub fn train(
    provider: &dyn GradProvider,
    seq: &GraphSequence,
    node_data: Vec<Box<dyn NodeData>>,
    eval_batches: &[Batch],
    cfg: &TrainConfig,
) -> Result<RunResult, String> {
    let n = seq.n;
    if node_data.len() != n {
        return Err(format!(
            "{} node data sources for {} nodes",
            node_data.len(),
            n
        ));
    }
    let d = provider.d_params();
    let init = provider.init_params();
    let mut nodes: Vec<NodeState> = node_data
        .into_iter()
        .map(|data| NodeState {
            params: init.clone(),
            opt: cfg.optimizer.build(d),
            data,
            last_loss: f64::NAN,
            pending: Vec::new(),
            error: None,
        })
        .collect();
    let pool = if cfg.threads == 0 {
        ThreadPool::with_default_size(16)
    } else {
        ThreadPool::new(cfg.threads)
    };
    let mut ledger = CommLedger::default();
    let n_msgs = nodes[0].opt.n_messages();
    // Persistent gossip scratch: one d-vector per node, reused every round
    // (no allocation on the hot path — see EXPERIMENTS.md §Perf).
    let mut scratch: Vec<Vec<f32>> =
        (0..n).map(|_| vec![0.0f32; d]).collect();
    // Parallel gossip only pays off when the row-combine work is large;
    // below this many f32 ops per node the scoped-thread overhead loses.
    let parallel_gossip = d.saturating_mul(4) >= 1 << 14;
    let mut result = RunResult {
        label: format!(
            "{} × {} × {}",
            provider.name(),
            seq.name,
            cfg.optimizer.label()
        ),
        records: Vec::new(),
    };

    for r in 0..cfg.rounds {
        let lr = cfg.lr_at(r) as f32;
        // 1+2. Local gradient + optimizer pre-mix (parallel over nodes).
        pool.for_each_mut(&mut nodes, |_, node| {
            let batch = node.data.next_train_batch();
            match provider.train_step(&node.params, &batch) {
                Ok((loss, grads)) => {
                    node.last_loss = loss as f64;
                    node.pending = node.opt.pre_mix(&node.params, &grads, lr);
                }
                Err(e) => node.error = Some(e),
            }
        });
        if let Some(e) = nodes.iter().find_map(|s| s.error.clone()) {
            return Err(format!("round {r}: {e}"));
        }

        // 3. Gossip each message over the current phase's sparse plan:
        // each node touches only its neighbor payloads (O(degree · d)).
        // The combine accumulates in f32: a gossip row has at most k+2
        // nonzeros with weights in [0,1], so the error is bounded by a few
        // ulps — and it is ~2.4x faster than f64 accumulation
        // (EXPERIMENTS.md §Perf).
        let plan = seq.phase(r);
        // Optimizer-requested damping: W̃ = (1−λ)W + λI (see
        // DecentralizedOptimizer::w_damping; λ = 1/2 for D²).
        let damping = nodes[0].opt.w_damping() as f32;
        for m in 0..n_msgs {
            let msgs: Vec<&[f32]> =
                nodes.iter().map(|s| s.pending[m].as_slice()).collect();
            let combine = |i: usize, out: &mut Vec<f32>| {
                let self_w = plan.self_weight(i) as f32 * (1.0 - damping)
                    + damping;
                let own = msgs[i];
                for (o, &s) in out.iter_mut().zip(own) {
                    *o = self_w * s;
                }
                for &(j, wij) in plan.neighbors(i) {
                    let wf = wij as f32 * (1.0 - damping);
                    if wf == 0.0 {
                        continue;
                    }
                    let src = msgs[j];
                    for (o, &s) in out.iter_mut().zip(src) {
                        *o += wf * s;
                    }
                }
            };
            if parallel_gossip {
                pool.for_each_mut(&mut scratch, combine);
            } else {
                for (i, out) in scratch.iter_mut().enumerate() {
                    combine(i, out);
                }
            }
            for (node, sc) in nodes.iter_mut().zip(scratch.iter_mut()) {
                std::mem::swap(&mut node.pending[m], sc);
            }
            ledger.record_round(plan, d, &cfg.cost);
        }

        // 4. Post-mix: commit new parameters. A node is "active" when it
        // had at least one gossip partner this phase.
        pool.for_each_mut(&mut nodes, |i, node| {
            let active = plan.is_active(i);
            let pending = std::mem::take(&mut node.pending);
            let new = node.opt.post_mix(pending, &node.params, lr, active);
            node.params = new;
        });

        // 5. Metrics.
        let is_eval = (cfg.eval_every > 0 && (r + 1) % cfg.eval_every == 0)
            || r + 1 == cfg.rounds;
        let mut rec = RoundRecord {
            round: r + 1,
            train_loss: nodes.iter().map(|s| s.last_loss).sum::<f64>()
                / n as f64,
            consensus_error: f64::NAN,
            test_loss: f64::NAN,
            test_acc: f64::NAN,
            cum_messages: ledger.messages,
            cum_bytes: ledger.bytes,
            sim_seconds: ledger.sim_seconds,
        };
        if is_eval {
            let params_f64: Vec<Vec<f64>> = nodes
                .iter()
                .map(|s| s.params.iter().map(|&x| x as f64).collect())
                .collect();
            rec.consensus_error = consensus::consensus_error(&params_f64);
            if !eval_batches.is_empty() {
                let avg = average_params(&nodes, d);
                let (loss, acc) =
                    evaluate(provider, &avg, eval_batches)?;
                rec.test_loss = loss;
                rec.test_acc = acc;
            }
            result.records.push(rec);
        } else {
            result.records.push(rec);
        }
    }
    Ok(result)
}

fn average_params(nodes: &[NodeState], d: usize) -> Vec<f32> {
    let n = nodes.len();
    let mut avg = vec![0.0f64; d];
    for s in nodes {
        for (a, &p) in avg.iter_mut().zip(&s.params) {
            *a += p as f64;
        }
    }
    avg.into_iter().map(|x| (x / n as f64) as f32).collect()
}

/// Evaluate params over a batch list; returns (mean loss, accuracy).
pub fn evaluate(
    provider: &dyn GradProvider,
    params: &[f32],
    batches: &[Batch],
) -> Result<(f64, f64), String> {
    let mut loss = 0.0f64;
    let mut correct = 0.0f64;
    let mut total = 0usize;
    for b in batches {
        let (l, c) = provider.eval_step(params, b)?;
        loss += l as f64;
        correct += c;
        total += b.label_count();
    }
    Ok((
        loss / batches.len().max(1) as f64,
        if total > 0 { correct / total as f64 } else { f64::NAN },
    ))
}

#[cfg(test)]
mod tests {
    use super::node_data::FixedBatch;
    use super::*;
    use crate::runtime::provider::QuadraticModel;
    use crate::topology::{base, baselines};
    use crate::util::rng::Rng;

    /// Quadratic decentralized problem: node i minimizes 0.5||x − c_i||²;
    /// the global optimum is mean(c_i). DSGD over a finite-time topology
    /// must drive both consensus error and distance-to-optimum to ~0.
    fn quadratic_setup(
        n: usize,
        d: usize,
        seed: u64,
    ) -> (QuadraticModel, Vec<Box<dyn NodeData>>, Vec<Vec<f32>>) {
        let mut rng = Rng::new(seed);
        let model = QuadraticModel::new(d);
        let targets: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..d).map(|_| rng.normal() as f32 * 3.0).collect())
            .collect();
        let data: Vec<Box<dyn NodeData>> = targets
            .iter()
            .map(|c| {
                Box::new(FixedBatch::new(QuadraticModel::target_batch(
                    c.clone(),
                ))) as Box<dyn NodeData>
            })
            .collect();
        (model, data, targets)
    }

    fn optimum(targets: &[Vec<f32>]) -> Vec<f64> {
        let n = targets.len();
        let d = targets[0].len();
        let mut o = vec![0.0f64; d];
        for t in targets {
            for (oi, &ti) in o.iter_mut().zip(t) {
                *oi += ti as f64 / n as f64;
            }
        }
        o
    }

    #[test]
    fn dsgd_on_base_graph_reaches_global_optimum() {
        // With a decaying step size (the paper's cosine schedule), DSGD on
        // a finite-time topology converges to the *global* optimum of the
        // heterogeneous quadratic: mean train loss -> opt loss and
        // consensus error -> 0. (With a constant step the stationary state
        // keeps an O(η²ζ²) consensus floor — that behavior is exercised in
        // the repro harness, not asserted here.)
        let n = 10;
        let (model, data, targets) = quadratic_setup(n, 6, 0);
        let seq = base::base(n, 1).unwrap();
        let cfg = TrainConfig {
            rounds: 400,
            lr: 0.3,
            warmup: 0,
            cosine: true,
            optimizer: OptimizerKind::Dsgd,
            eval_every: 0,
            threads: 2,
            ..Default::default()
        };
        let res = train(&model, &seq, data, &[], &cfg).unwrap();
        let last = res.records.last().unwrap();
        let opt = optimum(&targets);
        let opt_loss: f64 = targets
            .iter()
            .map(|c| {
                c.iter()
                    .zip(&opt)
                    .map(|(&ci, &oi)| 0.5 * (ci as f64 - oi).powi(2))
                    .sum::<f64>()
            })
            .sum::<f64>()
            / n as f64;
        assert!(
            (last.train_loss - opt_loss).abs() < 0.03 * opt_loss.max(1.0),
            "final loss {} vs optimal {}",
            last.train_loss,
            opt_loss
        );
        assert!(
            last.consensus_error < 1e-5,
            "consensus error {}",
            last.consensus_error
        );
    }

    #[test]
    fn base_graph_beats_ring_in_consensus_error() {
        // The paper's core training-side claim, on the controlled
        // quadratic: with heterogeneous targets, the finite-time topology
        // keeps node parameters far closer together than the ring. Compare
        // the consensus floor at matched (decayed) step size.
        let n = 24;
        let run = |seq: &GraphSequence| {
            let (model, data, _) = quadratic_setup(n, 4, 3);
            let cfg = TrainConfig {
                rounds: 120,
                lr: 0.2,
                warmup: 0,
                cosine: true,
                optimizer: OptimizerKind::Dsgd,
                eval_every: 0,
                threads: 2,
                ..Default::default()
            };
            train(&model, seq, data, &[], &cfg)
                .unwrap()
                .records
                .last()
                .unwrap()
                .consensus_error
        };
        let e_base = run(&base::base(n, 1).unwrap());
        let e_ring = run(&baselines::ring(n));
        assert!(
            e_base < e_ring / 5.0,
            "base-2 {e_base:.3e} should be well below ring {e_ring:.3e}"
        );
    }

    #[test]
    fn all_optimizers_run_on_training_loop() {
        let n = 6;
        for kind in [
            OptimizerKind::Dsgd,
            OptimizerKind::Dsgdm { momentum: 0.9 },
            OptimizerKind::QgDsgdm { momentum: 0.9 },
            OptimizerKind::D2,
            OptimizerKind::GradientTracking,
        ] {
            let (model, data, _) = quadratic_setup(n, 3, 1);
            let seq = base::base(n, 2).unwrap();
            let cfg = TrainConfig {
                rounds: 120,
                lr: 0.2,
                warmup: 0,
                cosine: true,
                optimizer: kind,
                eval_every: 0,
                threads: 1,
                ..Default::default()
            };
            let res = train(&model, &seq, data, &[], &cfg).unwrap();
            let last = res.records.last().unwrap();
            assert!(
                last.train_loss.is_finite(),
                "{}: loss diverged",
                kind.label()
            );
            assert!(
                last.consensus_error < 1e-3,
                "{}: consensus {:.2e}",
                kind.label(),
                last.consensus_error
            );
        }
    }

    #[test]
    fn gradient_tracking_doubles_comm() {
        let n = 5;
        let run = |kind| {
            let (model, data, _) = quadratic_setup(n, 3, 2);
            let seq = base::base(n, 1).unwrap();
            let cfg = TrainConfig {
                rounds: 10,
                lr: 0.1,
                warmup: 0,
                cosine: false,
                optimizer: kind,
                eval_every: 0,
                threads: 1,
                ..Default::default()
            };
            train(&model, &seq, data, &[], &cfg)
                .unwrap()
                .records
                .last()
                .unwrap()
                .cum_messages
        };
        let m_dsgd = run(OptimizerKind::Dsgd);
        let m_gt = run(OptimizerKind::GradientTracking);
        assert_eq!(m_gt, 2 * m_dsgd);
    }

    #[test]
    fn lr_schedule_shapes() {
        let cfg = TrainConfig {
            rounds: 100,
            lr: 1.0,
            warmup: 10,
            cosine: true,
            ..Default::default()
        };
        assert!((cfg.lr_at(0) - 0.1).abs() < 1e-9);
        assert!((cfg.lr_at(9) - 1.0).abs() < 1e-9);
        assert!((cfg.lr_at(10) - 1.0).abs() < 1e-9);
        assert!(cfg.lr_at(60) < 1.0);
        assert!(cfg.lr_at(99) < 0.01);
        // No warmup / no cosine.
        let flat = TrainConfig {
            rounds: 100,
            lr: 0.5,
            warmup: 0,
            cosine: false,
            ..Default::default()
        };
        assert_eq!(flat.lr_at(0), 0.5);
        assert_eq!(flat.lr_at(99), 0.5);
    }

    #[test]
    fn deterministic_across_thread_counts() {
        // Gossip order is data-independent, so results must be identical
        // with 1 or 4 threads.
        let run = |threads| {
            let (model, data, _) = quadratic_setup(8, 4, 5);
            let seq = base::base(8, 1).unwrap();
            let cfg = TrainConfig {
                rounds: 30,
                lr: 0.2,
                warmup: 0,
                cosine: false,
                optimizer: OptimizerKind::Dsgdm { momentum: 0.9 },
                eval_every: 0,
                threads,
                ..Default::default()
            };
            train(&model, &seq, data, &[], &cfg)
                .unwrap()
                .records
                .last()
                .unwrap()
                .train_loss
        };
        assert_eq!(run(1), run(4));
    }
}
