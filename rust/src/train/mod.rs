//! The decentralized training layer: hyperparameters ([`TrainConfig`]),
//! the f32 gossip-combine kernel shared by every execution backend, and
//! evaluation helpers.
//!
//! **Migration note.** The round protocol itself lives in
//! [`exec::TrainingWorkload`](crate::exec::TrainingWorkload) and runs on
//! any [`exec::Executor`](crate::exec::Executor) backend — analytic,
//! event-driven simnet, thread-parallel, or process-parallel. The old
//! `train()` wrapper served its one-release deprecation window and is
//! gone: build a `TrainingWorkload` and pick a backend (the returned
//! [`ExecTrace`](crate::exec::ExecTrace) carries the per-round records
//! plus simulated and measured clocks).
//!
//! Gossip walks each node's [`GossipPlan`](crate::topology::GossipPlan)
//! neighbor list — O(degree · d) per node per round — so per-round cost
//! scales with the real messages exchanged, not with n².

pub mod node_data;

use crate::comm::CostModel;
use crate::optim::OptimizerKind;
use crate::runtime::batch::Batch;
use crate::runtime::provider::GradProvider;
use crate::topology::GossipPlan;

/// One node's f32 gossip combine over `plan`'s neighbor list, with
/// optimizer damping λ (the engine mixes with W̃ = (1−λ)W + λI) and
/// tolerance for missing neighbor payloads: `get(j)` returns `None` when
/// peer `j`'s message was dropped or has not arrived (the simnet drivers),
/// in which case the surviving weights are renormalized to sum to 1.
///
/// With every payload present the arithmetic is bit-identical to the
/// bulk-synchronous trainer's hot combine — this is the single function
/// both the analytic trainer and the event-driven simnet trainer run, so
/// "simnet under an ideal network reproduces the trainer exactly" holds by
/// construction. Returns how many neighbor payloads were mixed.
pub fn gossip_combine<'a>(
    plan: &GossipPlan,
    i: usize,
    damping: f32,
    own: &[f32],
    get: impl Fn(usize) -> Option<&'a [f32]>,
    out: &mut [f32],
) -> usize {
    let row = plan.neighbors(i);
    gossip_combine_slots(plan, i, damping, own, |k| get(row[k].0), out)
}

/// The slot-indexed twin of [`gossip_combine`]: `get(k)` is keyed by
/// *neighbor-slot position* `k` (the index into `plan.neighbors(i)`)
/// instead of by peer id — the form the executors' availability tables
/// serve directly, so the hot combine does no per-neighbor peer-id
/// lookup. Arithmetic is bit-identical to the peer-keyed form.
pub fn gossip_combine_slots<'a>(
    plan: &GossipPlan,
    i: usize,
    damping: f32,
    own: &[f32],
    get: impl Fn(usize) -> Option<&'a [f32]>,
    out: &mut [f32],
) -> usize {
    let sw0 = plan.self_weight(i) as f32 * (1.0 - damping) + damping;
    let row = plan.neighbors(i);
    // Optimistic single pass: with every payload present (the
    // analytic/threaded/process common case) there is no renormalizing
    // to do, so skip the missing-weight pre-scan entirely and stream
    // the row through the fused combine kernel, four sources per tile.
    // The weights are wf·1.0 == wf bit-for-bit, so this is exactly the
    // two-pass arithmetic. On the first missing payload `out` (not yet
    // fully written) is abandoned and the slow path recomputes it from
    // scratch.
    let mut batch: [(&[f32], f32); 4] = [(own, 0.0); 4];
    let mut nb = 0usize;
    let mut scaled = false;
    let mut used = 0usize;
    for (k, &(_, wij)) in row.iter().enumerate() {
        let wf = wij as f32 * (1.0 - damping);
        if wf == 0.0 {
            continue;
        }
        match get(k) {
            None => {
                return combine_slots_renorm(plan, i, damping, own, get, out);
            }
            Some(src) => {
                batch[nb] = (src, wf);
                nb += 1;
                used += 1;
                if nb == batch.len() {
                    flush_combine(out, own, sw0, &batch[..nb], &mut scaled);
                    nb = 0;
                }
            }
        }
    }
    flush_combine(out, own, sw0, &batch[..nb], &mut scaled);
    used
}

/// Emit one combine tile: the first flush folds the `sw·own` scale into
/// the fused kernel, later flushes are pure multi-source axpys.
fn flush_combine(
    out: &mut [f32],
    own: &[f32],
    sw: f32,
    srcs: &[(&[f32], f32)],
    scaled: &mut bool,
) {
    if *scaled {
        crate::kernels::axpy_many_f32(out, srcs);
    } else {
        crate::kernels::combine_f32(out, own, sw, srcs);
        *scaled = true;
    }
}

/// The renormalizing slow path: at least one nonzero-weight payload is
/// missing, so pre-scan the row for the surviving mass, rescale, and
/// mix. Arithmetic (including the pre-scan's accumulation order) is the
/// original two-pass form, kernelized.
#[cold]
fn combine_slots_renorm<'a>(
    plan: &GossipPlan,
    i: usize,
    damping: f32,
    own: &[f32],
    get: impl Fn(usize) -> Option<&'a [f32]>,
    out: &mut [f32],
) -> usize {
    let sw0 = plan.self_weight(i) as f32 * (1.0 - damping) + damping;
    let row = plan.neighbors(i);
    let mut missing = 0.0f32;
    for (k, &(_, wij)) in row.iter().enumerate() {
        let wf = wij as f32 * (1.0 - damping);
        if wf != 0.0 && get(k).is_none() {
            missing += wf;
        }
    }
    let total = 1.0 - missing;
    let (sw, scale) = if total <= f32::EPSILON {
        // Every surviving weight vanished: keep the old value.
        (1.0, 0.0)
    } else {
        (sw0 / total, 1.0 / total)
    };
    let mut batch: [(&[f32], f32); 4] = [(own, 0.0); 4];
    let mut nb = 0usize;
    let mut scaled = false;
    let mut used = 0usize;
    for (k, &(_, wij)) in row.iter().enumerate() {
        let wf = wij as f32 * (1.0 - damping);
        if wf == 0.0 {
            continue;
        }
        if let Some(src) = get(k) {
            batch[nb] = (src, wf * scale);
            nb += 1;
            used += 1;
            if nb == batch.len() {
                flush_combine(out, own, sw, &batch[..nb], &mut scaled);
                nb = 0;
            }
        }
    }
    flush_combine(out, own, sw, &batch[..nb], &mut scaled);
    used
}

/// Training hyperparameters (paper Sec. H analogue).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub rounds: usize,
    pub lr: f64,
    /// Linear LR warmup rounds (paper: 10 epochs).
    pub warmup: usize,
    /// Cosine decay after warmup (paper: cosine scheduler).
    pub cosine: bool,
    pub optimizer: OptimizerKind,
    /// Evaluate every this many rounds (0 = only at the end).
    pub eval_every: usize,
    /// Worker threads for local gradient computation.
    pub threads: usize,
    pub cost: CostModel,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            rounds: 100,
            lr: 0.1,
            warmup: 10,
            cosine: true,
            optimizer: OptimizerKind::Dsgdm { momentum: 0.9 },
            eval_every: 10,
            threads: 0, // 0 = auto
            cost: CostModel::default(),
        }
    }
}

impl TrainConfig {
    /// LR at round r: linear warmup then (optionally) cosine decay to 0.
    pub fn lr_at(&self, r: usize) -> f64 {
        if self.warmup > 0 && r < self.warmup {
            return self.lr * (r + 1) as f64 / self.warmup as f64;
        }
        if !self.cosine || self.rounds <= self.warmup {
            return self.lr;
        }
        let t = (r - self.warmup) as f64
            / (self.rounds - self.warmup).max(1) as f64;
        self.lr * 0.5 * (1.0 + (std::f64::consts::PI * t).cos())
    }
}

/// Node-averaged parameter vector (f64 accumulation in node order) — the
/// model that gets evaluated at eval points, shared with the simnet
/// drivers so both paths average identically.
pub fn average_params<'a>(
    params: impl IntoIterator<Item = &'a [f32]>,
    d: usize,
) -> Vec<f32> {
    let mut avg = vec![0.0f64; d];
    let mut n = 0usize;
    for p in params {
        n += 1;
        for (a, &x) in avg.iter_mut().zip(p) {
            *a += x as f64;
        }
    }
    let n = n.max(1) as f64;
    avg.into_iter().map(|x| (x / n) as f32).collect()
}

/// Evaluate params over a batch list; returns (mean loss, accuracy).
pub fn evaluate(
    provider: &dyn GradProvider,
    params: &[f32],
    batches: &[Batch],
) -> Result<(f64, f64), String> {
    let mut loss = 0.0f64;
    let mut correct = 0.0f64;
    let mut total = 0usize;
    for b in batches {
        let (l, c) = provider.eval_step(params, b)?;
        loss += l as f64;
        correct += c;
        total += b.label_count();
    }
    Ok((
        loss / batches.len().max(1) as f64,
        if total > 0 { correct / total as f64 } else { f64::NAN },
    ))
}

#[cfg(test)]
mod tests {
    use super::node_data::{FixedBatch, NodeData};
    use super::*;
    use crate::exec::{AnalyticExecutor, Executor, TrainingWorkload};
    use crate::metrics::RunResult;
    use crate::runtime::provider::QuadraticModel;
    use crate::topology::{base, baselines, GraphSequence};
    use crate::util::rng::Rng;

    /// The executor-backed equivalent of the removed `train()` wrapper:
    /// run a [`TrainingWorkload`] on the analytic backend and keep the
    /// per-round records. These tests pin the training-layer *behavior*
    /// (convergence, optimizer coverage, determinism) on that path.
    fn run_train(
        provider: &dyn GradProvider,
        seq: &GraphSequence,
        node_data: Vec<Box<dyn NodeData>>,
        eval_batches: &[Batch],
        cfg: &TrainConfig,
    ) -> Result<RunResult, String> {
        let mut w =
            TrainingWorkload::new(provider, cfg, node_data, eval_batches);
        let exec = AnalyticExecutor::new(cfg.cost, cfg.threads);
        Ok(exec.run(&mut w, seq, cfg.rounds)?.run)
    }

    /// Quadratic decentralized problem: node i minimizes 0.5||x − c_i||²;
    /// the global optimum is mean(c_i). DSGD over a finite-time topology
    /// must drive both consensus error and distance-to-optimum to ~0.
    fn quadratic_setup(
        n: usize,
        d: usize,
        seed: u64,
    ) -> (QuadraticModel, Vec<Box<dyn NodeData>>, Vec<Vec<f32>>) {
        let mut rng = Rng::new(seed);
        let model = QuadraticModel::new(d);
        let targets: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..d).map(|_| rng.normal() as f32 * 3.0).collect())
            .collect();
        let data: Vec<Box<dyn NodeData>> = targets
            .iter()
            .map(|c| {
                Box::new(FixedBatch::new(QuadraticModel::target_batch(
                    c.clone(),
                ))) as Box<dyn NodeData>
            })
            .collect();
        (model, data, targets)
    }

    fn optimum(targets: &[Vec<f32>]) -> Vec<f64> {
        let n = targets.len();
        let d = targets[0].len();
        let mut o = vec![0.0f64; d];
        for t in targets {
            for (oi, &ti) in o.iter_mut().zip(t) {
                *oi += ti as f64 / n as f64;
            }
        }
        o
    }

    #[test]
    fn dsgd_on_base_graph_reaches_global_optimum() {
        // With a decaying step size (the paper's cosine schedule), DSGD on
        // a finite-time topology converges to the *global* optimum of the
        // heterogeneous quadratic: mean train loss -> opt loss and
        // consensus error -> 0. (With a constant step the stationary state
        // keeps an O(η²ζ²) consensus floor — that behavior is exercised in
        // the repro harness, not asserted here.)
        let n = 10;
        let (model, data, targets) = quadratic_setup(n, 6, 0);
        let seq = base::base(n, 1).unwrap();
        let cfg = TrainConfig {
            rounds: 400,
            lr: 0.3,
            warmup: 0,
            cosine: true,
            optimizer: OptimizerKind::Dsgd,
            eval_every: 0,
            threads: 2,
            ..Default::default()
        };
        let res = run_train(&model, &seq, data, &[], &cfg).unwrap();
        let last = res.records.last().unwrap();
        let opt = optimum(&targets);
        let opt_loss: f64 = targets
            .iter()
            .map(|c| {
                c.iter()
                    .zip(&opt)
                    .map(|(&ci, &oi)| 0.5 * (ci as f64 - oi).powi(2))
                    .sum::<f64>()
            })
            .sum::<f64>()
            / n as f64;
        assert!(
            (last.train_loss - opt_loss).abs() < 0.03 * opt_loss.max(1.0),
            "final loss {} vs optimal {}",
            last.train_loss,
            opt_loss
        );
        assert!(
            last.consensus_error < 1e-5,
            "consensus error {}",
            last.consensus_error
        );
    }

    #[test]
    fn base_graph_beats_ring_in_consensus_error() {
        // The paper's core training-side claim, on the controlled
        // quadratic: with heterogeneous targets, the finite-time topology
        // keeps node parameters far closer together than the ring. Compare
        // the consensus floor at matched (decayed) step size.
        let n = 24;
        let run = |seq: &GraphSequence| {
            let (model, data, _) = quadratic_setup(n, 4, 3);
            let cfg = TrainConfig {
                rounds: 120,
                lr: 0.2,
                warmup: 0,
                cosine: true,
                optimizer: OptimizerKind::Dsgd,
                eval_every: 0,
                threads: 2,
                ..Default::default()
            };
            run_train(&model, seq, data, &[], &cfg)
                .unwrap()
                .records
                .last()
                .unwrap()
                .consensus_error
        };
        let e_base = run(&base::base(n, 1).unwrap());
        let e_ring = run(&baselines::ring(n));
        assert!(
            e_base < e_ring / 5.0,
            "base-2 {e_base:.3e} should be well below ring {e_ring:.3e}"
        );
    }

    #[test]
    fn all_optimizers_run_on_training_loop() {
        let n = 6;
        for kind in [
            OptimizerKind::Dsgd,
            OptimizerKind::Dsgdm { momentum: 0.9 },
            OptimizerKind::QgDsgdm { momentum: 0.9 },
            OptimizerKind::D2,
            OptimizerKind::GradientTracking,
        ] {
            let (model, data, _) = quadratic_setup(n, 3, 1);
            let seq = base::base(n, 2).unwrap();
            let cfg = TrainConfig {
                rounds: 120,
                lr: 0.2,
                warmup: 0,
                cosine: true,
                optimizer: kind,
                eval_every: 0,
                threads: 1,
                ..Default::default()
            };
            let res = run_train(&model, &seq, data, &[], &cfg).unwrap();
            let last = res.records.last().unwrap();
            assert!(
                last.train_loss.is_finite(),
                "{}: loss diverged",
                kind.label()
            );
            assert!(
                last.consensus_error < 1e-3,
                "{}: consensus {:.2e}",
                kind.label(),
                last.consensus_error
            );
        }
    }

    #[test]
    fn gradient_tracking_doubles_comm() {
        let n = 5;
        let run = |kind| {
            let (model, data, _) = quadratic_setup(n, 3, 2);
            let seq = base::base(n, 1).unwrap();
            let cfg = TrainConfig {
                rounds: 10,
                lr: 0.1,
                warmup: 0,
                cosine: false,
                optimizer: kind,
                eval_every: 0,
                threads: 1,
                ..Default::default()
            };
            run_train(&model, &seq, data, &[], &cfg)
                .unwrap()
                .records
                .last()
                .unwrap()
                .cum_messages
        };
        let m_dsgd = run(OptimizerKind::Dsgd);
        let m_gt = run(OptimizerKind::GradientTracking);
        assert_eq!(m_gt, 2 * m_dsgd);
    }

    #[test]
    fn lr_schedule_shapes() {
        let cfg = TrainConfig {
            rounds: 100,
            lr: 1.0,
            warmup: 10,
            cosine: true,
            ..Default::default()
        };
        assert!((cfg.lr_at(0) - 0.1).abs() < 1e-9);
        assert!((cfg.lr_at(9) - 1.0).abs() < 1e-9);
        assert!((cfg.lr_at(10) - 1.0).abs() < 1e-9);
        assert!(cfg.lr_at(60) < 1.0);
        assert!(cfg.lr_at(99) < 0.01);
        // No warmup / no cosine.
        let flat = TrainConfig {
            rounds: 100,
            lr: 0.5,
            warmup: 0,
            cosine: false,
            ..Default::default()
        };
        assert_eq!(flat.lr_at(0), 0.5);
        assert_eq!(flat.lr_at(99), 0.5);
    }

    #[test]
    fn gossip_combine_renormalizes_missing_payloads() {
        use crate::topology::GossipPlan;
        // Node 0 mixes peers 1 and 2 with weight 1/4 each (self 1/2).
        let plan = GossipPlan::from_undirected(
            3,
            &[(0, 1, 0.25), (0, 2, 0.25)],
        );
        let msgs: Vec<Vec<f32>> = vec![vec![1.0], vec![5.0], vec![9.0]];
        let refs: Vec<&[f32]> = msgs.iter().map(|m| m.as_slice()).collect();
        // All present: plain weighted combine.
        let mut out = vec![0.0f32];
        let used =
            gossip_combine(&plan, 0, 0.0, refs[0], |j| Some(refs[j]), &mut out);
        assert_eq!(used, 2);
        assert!((out[0] - (0.5 + 1.25 + 2.25)).abs() < 1e-6);
        // Peer 2 missing: self 2/3, peer1 1/3.
        let mut out = vec![0.0f32];
        let used = gossip_combine(
            &plan,
            0,
            0.0,
            refs[0],
            |j| if j == 1 { Some(refs[1]) } else { None },
            &mut out,
        );
        assert_eq!(used, 1);
        assert!((out[0] - 7.0 / 3.0).abs() < 1e-6, "got {}", out[0]);
        // All missing: node keeps its own value.
        let mut out = vec![0.0f32];
        assert_eq!(
            gossip_combine(&plan, 0, 0.0, refs[0], |_| None, &mut out),
            0
        );
        assert!((out[0] - 1.0).abs() < 1e-7);
        // Damping λ=1/2 with a missing peer still yields a stochastic row:
        // constant input stays fixed.
        let ones: Vec<Vec<f32>> = vec![vec![3.0]; 3];
        let orefs: Vec<&[f32]> = ones.iter().map(|m| m.as_slice()).collect();
        let mut out = vec![0.0f32];
        gossip_combine(
            &plan,
            0,
            0.5,
            orefs[0],
            |j| if j == 1 { Some(orefs[1]) } else { None },
            &mut out,
        );
        assert!((out[0] - 3.0).abs() < 1e-6, "got {}", out[0]);
    }

    #[test]
    fn deterministic_across_thread_counts() {
        // Gossip order is data-independent, so results must be identical
        // with 1 or 4 threads.
        let run = |threads| {
            let (model, data, _) = quadratic_setup(8, 4, 5);
            let seq = base::base(8, 1).unwrap();
            let cfg = TrainConfig {
                rounds: 30,
                lr: 0.2,
                warmup: 0,
                cosine: false,
                optimizer: OptimizerKind::Dsgdm { momentum: 0.9 },
                eval_every: 0,
                threads,
                ..Default::default()
            };
            run_train(&model, &seq, data, &[], &cfg)
                .unwrap()
                .records
                .last()
                .unwrap()
                .train_loss
        };
        assert_eq!(run(1), run(4));
    }
}
