//! Per-node batch sources feeding the training loop.

use std::sync::Arc;

use crate::data::corpus::CharCorpus;
use crate::data::synth::{ClassificationDataset, NodeSampler};
use crate::exec::wire::{ByteReader, ByteWriter};
use crate::runtime::batch::Batch;

/// A node's stream of training batches.
pub trait NodeData: Send {
    fn next_train_batch(&mut self) -> Batch;

    /// Borrowing variant of [`next_train_batch`](Self::next_train_batch):
    /// write the round's batch into `out`, reusing its buffers. The
    /// default delegates to the allocating method; sources with stable
    /// batch shapes (e.g. [`FixedBatch`]) override it so the
    /// steady-state training round allocates nothing.
    fn next_train_batch_into(&mut self, out: &mut Batch) {
        *out = self.next_train_batch();
    }

    /// Number of local examples (for diagnostics).
    fn shard_size(&self) -> usize;

    /// Whether this source carries resume-relevant cursor state. Sources
    /// that answer `true` get a tagged cursor section in the node
    /// checkpoint ([`cursor_save`](Self::cursor_save) /
    /// [`cursor_load`](Self::cursor_load)); round-deterministic sources
    /// ([`FixedBatch`]) keep the default `false` and stay out of the blob.
    fn has_cursor(&self) -> bool {
        false
    }

    /// Serialize the batch-stream cursor (exact bit patterns).
    fn cursor_save(&self, _w: &mut ByteWriter) {}

    /// Restore a cursor written by [`cursor_save`](Self::cursor_save).
    fn cursor_load(&mut self, _r: &mut ByteReader) -> Result<(), String> {
        Ok(())
    }
}

/// Always returns the same batch (quadratic targets, overfit probes).
pub struct FixedBatch {
    batch: Batch,
}

impl FixedBatch {
    pub fn new(batch: Batch) -> Self {
        FixedBatch { batch }
    }
}

impl NodeData for FixedBatch {
    fn next_train_batch(&mut self) -> Batch {
        self.batch.clone()
    }
    fn next_train_batch_into(&mut self, out: &mut Batch) {
        out.clone_from(&self.batch);
    }
    fn shard_size(&self) -> usize {
        self.batch.batch_size()
    }
}

/// Classification shard: samples `batch_size` examples per round from this
/// node's Dirichlet shard.
pub struct ClassificationShard {
    ds: Arc<ClassificationDataset>,
    sampler: NodeSampler,
    batch_size: usize,
}

impl ClassificationShard {
    pub fn new(
        ds: Arc<ClassificationDataset>,
        indices: Vec<usize>,
        batch_size: usize,
        seed: u64,
    ) -> Self {
        ClassificationShard {
            ds,
            sampler: NodeSampler::new(indices, seed),
            batch_size,
        }
    }
}

impl NodeData for ClassificationShard {
    fn next_train_batch(&mut self) -> Batch {
        self.sampler.next_batch(&self.ds, self.batch_size)
    }
    fn shard_size(&self) -> usize {
        self.sampler.shard_size()
    }
    fn has_cursor(&self) -> bool {
        true
    }
    fn cursor_save(&self, w: &mut ByteWriter) {
        self.sampler.state_save(w);
    }
    fn cursor_load(&mut self, r: &mut ByteReader) -> Result<(), String> {
        self.sampler.state_load(r)
    }
}

/// LM shard over corpus documents.
pub struct CorpusShard {
    corpus: Arc<CharCorpus>,
    sampler: NodeSampler,
    batch_size: usize,
}

impl CorpusShard {
    pub fn new(
        corpus: Arc<CharCorpus>,
        indices: Vec<usize>,
        batch_size: usize,
        seed: u64,
    ) -> Self {
        CorpusShard {
            corpus,
            sampler: NodeSampler::new(indices, seed),
            batch_size,
        }
    }
}

impl NodeData for CorpusShard {
    fn next_train_batch(&mut self) -> Batch {
        let idx = self.sampler.next_indices(self.batch_size);
        self.corpus.gather(&idx)
    }
    fn shard_size(&self) -> usize {
        self.sampler.shard_size()
    }
    fn has_cursor(&self) -> bool {
        true
    }
    fn cursor_save(&self, w: &mut ByteWriter) {
        self.sampler.state_save(w);
    }
    fn cursor_load(&mut self, r: &mut ByteReader) -> Result<(), String> {
        self.sampler.state_load(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_mixture;
    use crate::util::rng::Rng;

    #[test]
    fn classification_shard_yields_shaped_batches() {
        let mut rng = Rng::new(0);
        let ds = Arc::new(gaussian_mixture(100, 8, 4, 1.0, 0.2, &mut rng));
        let mut shard =
            ClassificationShard::new(ds, (0..50).collect(), 16, 1);
        let b = shard.next_train_batch();
        assert_eq!(b.x_shape, vec![16, 8]);
        assert_eq!(shard.shard_size(), 50);
        b.validate().unwrap();
    }

    #[test]
    fn corpus_shard_yields_lm_batches() {
        let mut rng = Rng::new(1);
        let corpus =
            Arc::new(crate::data::corpus::generate(40, 32, 2, &mut rng));
        let mut shard = CorpusShard::new(corpus, (0..40).collect(), 4, 2);
        let b = shard.next_train_batch();
        assert_eq!(b.x_shape, vec![4, 32]);
        assert_eq!(b.y_shape, vec![4, 32]);
    }

    #[test]
    fn shard_cursor_round_trips_and_replays_the_batch_stream() {
        let mut rng = Rng::new(3);
        let ds = Arc::new(gaussian_mixture(120, 6, 3, 1.0, 0.2, &mut rng));
        let mut shard =
            ClassificationShard::new(ds.clone(), (0..60).collect(), 16, 9);
        assert!(shard.has_cursor());
        // Advance mid-epoch (and past a reshuffle) before snapshotting.
        for _ in 0..5 {
            shard.next_train_batch();
        }
        let mut w = ByteWriter::new();
        shard.cursor_save(&mut w);
        let bytes = w.finish();
        // A freshly built shard + cursor restore must replay the exact
        // same stream the original produces from here on.
        let mut resumed =
            ClassificationShard::new(ds, (0..60).collect(), 16, 9);
        let mut r = ByteReader::new(&bytes);
        resumed.cursor_load(&mut r).unwrap();
        r.expect_end().unwrap();
        for _ in 0..8 {
            assert_eq!(resumed.next_train_batch(), shard.next_train_batch());
        }
        // A cursor from a different shard size is a clean error.
        let mut wrong = ClassificationShard::new(
            Arc::new(gaussian_mixture(120, 6, 3, 1.0, 0.2, &mut rng)),
            (0..30).collect(),
            16,
            9,
        );
        let mut r = ByteReader::new(&bytes);
        let err = wrong.cursor_load(&mut r).unwrap_err();
        assert!(err.contains("shard has"), "{err}");
        // FixedBatch stays cursor-free.
        let fb = FixedBatch::new(
            crate::runtime::provider::QuadraticModel::target_batch(vec![
                1.0,
            ]),
        );
        assert!(!fb.has_cursor());
    }

    #[test]
    fn fixed_batch_repeats() {
        let batch = crate::runtime::provider::QuadraticModel::target_batch(
            vec![1.0, 2.0],
        );
        let mut fb = FixedBatch::new(batch.clone());
        assert_eq!(fb.next_train_batch(), batch);
        assert_eq!(fb.next_train_batch(), batch);
    }
}
