//! Communication accounting: the "communication efficiency" axis of every
//! figure in the paper.
//!
//! The paper measures communication cost by the topology's maximum degree
//! (each neighbor exchange moves one full parameter vector). This module
//! turns that into concrete per-round accounting — bytes sent per node,
//! aggregate bytes, and an α–β (latency–bandwidth) time model so the
//! accuracy-vs-cost trade-off can be plotted in seconds as well as rounds.
//!
//! Accounting reads the sparse [`GossipPlan`] directly: the message count
//! is the plan's stored entry count (every entry is one directed
//! `peer → node` payload), O(1) per phase — no dense matrix is scanned.

use crate::topology::{GossipPlan, GraphSequence};

/// α–β cost model: sending an s-byte message costs `alpha + beta * s`
/// seconds; a round's cost is the *maximum* over nodes (bulk-synchronous),
/// with each node's sends serialized over its degree.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Per-message latency (seconds). Default 1e-4 (LAN-ish RTT/2).
    pub alpha: f64,
    /// Per-byte cost (seconds/byte). Default 8e-10 (~10 Gbit/s).
    pub beta: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel { alpha: 1e-4, beta: 8e-10 }
    }
}

/// Communication statistics for one gossip phase.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PhaseComm {
    /// Directed messages sent this phase (each carries a full vector).
    pub messages: usize,
    /// Maximum per-node degree this phase.
    pub max_degree: usize,
}

/// Per-phase message counts for a plan.
pub fn phase_comm(plan: &GossipPlan) -> PhaseComm {
    PhaseComm { messages: plan.messages(), max_degree: plan.max_degree() }
}

/// Cumulative communication ledger for a training/consensus run.
#[derive(Debug, Clone, Default)]
pub struct CommLedger {
    /// Total directed messages.
    pub messages: u64,
    /// Total payload bytes (messages × d × 4).
    pub bytes: u64,
    /// Simulated wall-clock seconds under the α–β model.
    pub sim_seconds: f64,
    /// Rounds recorded.
    pub rounds: u64,
    /// **Measured** serialized bytes that crossed a real socket, exact —
    /// every frame byte (headers, payloads, checksums, both directions at
    /// the coordinator). Only the process backend moves real frames, so
    /// this stays 0 everywhere else; `bytes` above is the *model* count
    /// (payload floats × directed sends) and keeps its meaning on every
    /// backend.
    pub bytes_on_wire: u64,
}

impl CommLedger {
    /// Record one gossip round over `plan` with `d`-dimensional f32
    /// parameters.
    pub fn record_round(
        &mut self,
        plan: &GossipPlan,
        d: usize,
        cost: &CostModel,
    ) {
        self.record_round_bytes(plan, (d * 4) as u64, cost);
    }

    /// Like [`CommLedger::record_round`], but with an explicit per-message
    /// payload size — the executor layer serves payloads that are not
    /// always f32 vectors (f64 consensus values, message bundles).
    pub fn record_round_bytes(
        &mut self,
        plan: &GossipPlan,
        payload_bytes: u64,
        cost: &CostModel,
    ) {
        let pc = phase_comm(plan);
        self.messages += pc.messages as u64;
        self.bytes += pc.messages as u64 * payload_bytes;
        // Bulk-synchronous round time: the busiest node serializes its
        // sends.
        self.sim_seconds += pc.max_degree as f64
            * (cost.alpha + cost.beta * payload_bytes as f64);
        self.rounds += 1;
    }

    /// Record `count` directed message sends of `d`-dimensional f32
    /// payloads *without* advancing the analytic clock — the event-driven
    /// simnet drivers count real sends one by one and own the clock
    /// themselves (see [`CommLedger::advance_clock_to`]).
    pub fn record_sends(&mut self, count: usize, d: usize) {
        self.record_payload_sends(count, (d * 4) as u64);
    }

    /// Record `count` directed sends of `payload_bytes`-sized messages
    /// without touching the clock (byte-explicit twin of
    /// [`CommLedger::record_sends`]).
    pub fn record_payload_sends(&mut self, count: usize, payload_bytes: u64) {
        self.messages += count as u64;
        self.bytes += count as u64 * payload_bytes;
    }

    /// Advance the simulated clock to an event-driven timestamp. Monotone:
    /// never moves the clock backwards.
    pub fn advance_clock_to(&mut self, t: f64) {
        if t > self.sim_seconds {
            self.sim_seconds = t;
        }
    }

    /// Count one completed round (event-driven drivers call this at each
    /// phase barrier / global round completion).
    pub fn bump_round(&mut self) {
        self.rounds += 1;
    }

    /// Average bytes per node per round.
    pub fn bytes_per_node_round(&self, n: usize) -> f64 {
        if self.rounds == 0 || n == 0 {
            return 0.0;
        }
        self.bytes as f64 / (self.rounds as f64 * n as f64)
    }
}

/// Summary of a full sweep of a sequence: the paper's Table-1 style
/// communication profile.
#[derive(Debug, Clone)]
pub struct SequenceCommProfile {
    pub name: String,
    pub n: usize,
    pub len: usize,
    pub max_degree: usize,
    /// Messages for one full sweep of all phases.
    pub messages_per_sweep: usize,
    /// Simulated seconds per sweep for d-dimensional params.
    pub seconds_per_sweep: f64,
}

pub fn profile(
    seq: &GraphSequence,
    d: usize,
    cost: &CostModel,
) -> SequenceCommProfile {
    let mut ledger = CommLedger::default();
    for plan in &seq.phases {
        ledger.record_round(plan, d, cost);
    }
    SequenceCommProfile {
        name: seq.name.clone(),
        n: seq.n,
        len: seq.len(),
        max_degree: seq.max_degree(),
        messages_per_sweep: ledger.messages as usize,
        seconds_per_sweep: ledger.sim_seconds,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{base, baselines};

    #[test]
    fn ring_message_count() {
        // Ring of n: 2n directed messages per round (each node sends to 2
        // neighbors).
        let seq = baselines::ring(10);
        let pc = phase_comm(&seq.phases[0]);
        assert_eq!(pc.messages, 20);
        assert_eq!(pc.max_degree, 2);
    }

    #[test]
    fn base2_cheaper_than_exp_per_round() {
        // The headline trade-off: Base-2 (degree 1) moves ~n messages per
        // round; exp graph moves n·⌈log2 n⌉.
        let n = 25;
        let base = base::base(n, 1).unwrap();
        let exp = baselines::exponential(n);
        let bmax = base
            .phases
            .iter()
            .map(|w| phase_comm(w).messages)
            .max()
            .unwrap();
        let e = phase_comm(&exp.phases[0]).messages;
        assert!(bmax <= n, "base-2 sends at most n messages ({bmax})");
        assert_eq!(e, n * 5); // ⌈log2 25⌉ = 5
    }

    #[test]
    fn ledger_accumulates() {
        let seq = baselines::ring(8);
        let cost = CostModel::default();
        let mut ledger = CommLedger::default();
        for _ in 0..10 {
            ledger.record_round(&seq.phases[0], 1000, &cost);
        }
        assert_eq!(ledger.rounds, 10);
        assert_eq!(ledger.messages, 160);
        assert_eq!(ledger.bytes, 160 * 4000);
        assert!(ledger.sim_seconds > 0.0);
        // 640 kB over 10 rounds × 8 nodes = 8 kB per node-round.
        assert!((ledger.bytes_per_node_round(8) - 8_000.0).abs() < 1e-9);
    }

    #[test]
    fn alpha_beta_round_cost_is_degree_serialized_max() {
        // The analytic contract the simnet engine generalizes: one
        // bulk-synchronous round costs exactly max-degree sends of
        // `alpha + beta·payload` seconds each — the busiest node
        // serializes its sends, everyone else overlaps under the max.
        let cost = CostModel { alpha: 3e-3, beta: 2e-9 };
        let d = 1_000usize;
        let payload = (d * 4) as f64;
        // Ring: every node has degree 2.
        let ring = baselines::ring(8);
        let mut ledger = CommLedger::default();
        ledger.record_round(&ring.phases[0], d, &cost);
        assert_eq!(
            ledger.sim_seconds,
            2.0 * (cost.alpha + cost.beta * payload)
        );
        // Exp graph at n=32: max degree 5, so 5 serialized sends.
        let exp = baselines::exponential(32);
        let mut ledger = CommLedger::default();
        ledger.record_round(&exp.phases[0], d, &cost);
        assert_eq!(
            ledger.sim_seconds,
            5.0 * (cost.alpha + cost.beta * payload)
        );
        // Two rounds accumulate linearly.
        ledger.record_round(&exp.phases[0], d, &cost);
        assert_eq!(
            ledger.sim_seconds,
            5.0 * (cost.alpha + cost.beta * payload) * 2.0
        );
    }

    #[test]
    fn event_driven_ledger_methods() {
        let mut ledger = CommLedger::default();
        ledger.record_sends(3, 100); // 3 payloads of 400 bytes
        assert_eq!(ledger.messages, 3);
        assert_eq!(ledger.bytes, 1200);
        assert_eq!(ledger.sim_seconds, 0.0); // sends don't move the clock
        ledger.advance_clock_to(1.5);
        ledger.advance_clock_to(0.5); // monotone: no going back
        assert_eq!(ledger.sim_seconds, 1.5);
        ledger.bump_round();
        assert_eq!(ledger.rounds, 1);
    }

    #[test]
    fn wire_bytes_are_separate_from_model_bytes() {
        // bytes = α–β model payload accounting; bytes_on_wire = measured
        // serialized frames, assigned by the process coordinator from
        // its single running frame counter. They never mix.
        let mut ledger = CommLedger::default();
        ledger.record_sends(2, 100);
        assert_eq!(ledger.bytes_on_wire, 0, "model accounting stays off it");
        ledger.bytes_on_wire = 1000;
        assert_eq!(ledger.bytes, 800);
        assert_eq!(ledger.sim_seconds, 0.0);
    }

    #[test]
    fn alpha_beta_scaling() {
        let seq = baselines::exponential(32); // degree 5
        let w = &seq.phases[0];
        let mut cheap = CommLedger::default();
        let mut slow = CommLedger::default();
        cheap.record_round(w, 100, &CostModel { alpha: 1e-5, beta: 1e-10 });
        slow.record_round(w, 100, &CostModel { alpha: 1e-3, beta: 1e-10 });
        assert!(slow.sim_seconds > cheap.sim_seconds * 50.0);
    }

    #[test]
    fn profile_shape() {
        let seq = base::base(25, 4).unwrap();
        let p = profile(&seq, 26122, &CostModel::default());
        assert_eq!(p.n, 25);
        assert_eq!(p.len, seq.len());
        assert!(p.max_degree <= 4);
        assert!(p.messages_per_sweep > 0);
        assert!(p.seconds_per_sweep > 0.0);
    }

    #[test]
    fn large_n_profile_is_cheap() {
        // O(1) message counting from the plan: profiling Base-2 at n=8192
        // touches no n×n structure.
        let seq = base::base(8192, 1).unwrap();
        let p = profile(&seq, 64, &CostModel::default());
        assert!(p.messages_per_sweep <= seq.len() * 8192);
        assert_eq!(p.max_degree, 1);
    }
}
